//! Brain Simulation Broadcast (BSB) — the paper's §V.2 announced
//! communication upgrade: "a broadcast acceleration library specifically
//! designed for this communication pattern, which automatically
//! packs/unpacks spikes into/from messages and adaptively routes the
//! messages among processes to decrease the number of small messages".
//!
//! Implemented here as the paper describes it:
//!
//! * **Packing** — spike gids within a window are sorted and
//!   delta-encoded with a LEB128-style varint (most deltas fit one
//!   byte, vs 8 B/spike on the naive wire), plus the emission-step
//!   offsets packed per window;
//! * **Adaptive routing** — below a message-count threshold, ranks
//!   forward through a radix-k dissemination tree so each rank sends
//!   O(k·log_k R) aggregated messages instead of R-1 small ones; above
//!   it (dense traffic) direct exchange is cheaper. The choice is made
//!   per window from the measured payload;
//! * **Producer-consumer interface** — `push` spikes as they are
//!   emitted, `seal` the window, `drain` the remote spikes, matching the
//!   dedicated-communication-thread usage of §III.C.2.
//!
//! Since the TCP rank runtime ([`crate::comm::tcp`]) this codec is the
//! **actual on-the-wire format** between OS processes, which makes it a
//! trust boundary: decoding is fully fallible ([`CodecError`]) and never
//! panics on truncated, bit-flipped or adversarial input. A window
//! exchange travels as one [`encode_frame`] payload — varint window
//! counter, varint window start, then the packed spike list — inside a
//! length-prefixed frame written by the transport.

use std::fmt;

use super::{SpikeMsg, SpikePacket};
use crate::Gid;

/// Longest legal varint: 10 bytes carry 70 payload bits; a u64 needs
/// exactly that when every byte is a continuation.
const MAX_VARINT_BYTES: usize = 10;

/// A malformed wire payload. Every decoding path returns this instead of
/// panicking — over a socket the peer's bytes are untrusted input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CodecError {
    /// The buffer ended mid-varint or before the declared spike count
    /// was decoded.
    Truncated,
    /// A varint ran past 10 bytes / shifted beyond 63 bits.
    VarintOverflow,
    /// A decoded delta pushed a step or gid outside the 32-bit domain.
    ValueOverflow,
    /// The declared spike count disagrees with the frame length (bytes
    /// left over after the last spike).
    LengthMismatch { declared: u64, used: usize, len: usize },
    /// A spike predates the window it is being packed into
    /// (encode-side validation).
    SpikeBeforeWindow { step: u32, window_start: u32 },
    /// An assembled merged frame exceeds the transport's frame bound
    /// (encode-side validation; a relay merging many members' packets
    /// must refuse to emit a frame the receiver would reject).
    Oversize { bytes: usize, limit: usize },
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Truncated => {
                write!(f, "truncated spike packet")
            }
            CodecError::VarintOverflow => {
                write!(f, "varint exceeds 64 bits")
            }
            CodecError::ValueOverflow => {
                write!(f, "decoded step/gid outside the 32-bit domain")
            }
            CodecError::LengthMismatch { declared, used, len } => write!(
                f,
                "spike count disagrees with frame length \
                 ({declared} spikes declared, {used} of {len} bytes used)"
            ),
            CodecError::SpikeBeforeWindow { step, window_start } => {
                write!(
                    f,
                    "spike at step {step} predates window start \
                     {window_start}"
                )
            }
            CodecError::Oversize { bytes, limit } => write!(
                f,
                "merged frame of {bytes} bytes exceeds the \
                 {limit}-byte bound"
            ),
        }
    }
}

impl std::error::Error for CodecError {}

/// Varint (LEB128) encode. Shared with the `serve` control protocol,
/// which reuses the BSB codec's varint discipline.
#[inline]
pub(crate) fn put_varint(out: &mut Vec<u8>, mut x: u64) {
    loop {
        let b = (x & 0x7f) as u8;
        x >>= 7;
        if x == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Varint decode; advances `pos`. Fallible: a buffer that ends
/// mid-varint is [`CodecError::Truncated`], a varint longer than
/// [`MAX_VARINT_BYTES`] or carrying bits past 63 is
/// [`CodecError::VarintOverflow`].
#[inline]
pub(crate) fn get_varint(
    buf: &[u8],
    pos: &mut usize,
) -> Result<u64, CodecError> {
    let mut x = 0u64;
    let mut shift = 0u32;
    for _ in 0..MAX_VARINT_BYTES {
        let b = *buf.get(*pos).ok_or(CodecError::Truncated)?;
        *pos += 1;
        let low = (b & 0x7f) as u64;
        // the 10th byte (shift 63) may only contribute the final bit
        if shift == 63 && low > 1 {
            return Err(CodecError::VarintOverflow);
        }
        x |= low << shift;
        if b & 0x80 == 0 {
            return Ok(x);
        }
        shift += 7;
    }
    Err(CodecError::VarintOverflow)
}

/// Encode a **sorted, duplicate-free** gid list as varint count plus
/// delta-coded varint gids — the wire form of one rank's interest
/// subscription in the build-time routing collective
/// ([`crate::comm::Communicator::alltoall`]). Sorted subscription lists
/// delta-code down to ~1 byte/gid for the dense sub-graph interest
/// sets the indegree decomposition produces.
pub fn encode_gid_list(gids: &[Gid]) -> Vec<u8> {
    debug_assert!(gids.windows(2).all(|w| w[0] < w[1]));
    let mut out = Vec::with_capacity(gids.len() + 4);
    put_varint(&mut out, gids.len() as u64);
    let mut prev = 0u64;
    for (i, &g) in gids.iter().enumerate() {
        let g = g as u64;
        // the first gid travels absolute; later gids as gap - 1 (gaps
        // are >= 1 in a strictly increasing list, so runs cost 1 byte)
        put_varint(&mut out, g - prev - u64::from(i > 0));
        prev = g;
    }
    out
}

/// Decode an [`encode_gid_list`] payload back into the sorted gid
/// list. Total like the rest of the codec: truncated buffers, overlong
/// varints, gids escaping the 32-bit domain and trailing bytes are all
/// [`CodecError`]s, never panics.
pub fn decode_gid_list(buf: &[u8]) -> Result<Vec<Gid>, CodecError> {
    let mut pos = 0usize;
    let n = get_varint(buf, &mut pos)?;
    // same pre-allocation guard as `unpack_at`: a declared count must
    // be plausible for the bytes actually present (>= 1 byte per gid)
    if n as usize > buf.len() {
        return Err(CodecError::Truncated);
    }
    let mut gids = Vec::with_capacity(n as usize);
    let mut prev = 0u64;
    for i in 0..n {
        let delta = get_varint(buf, &mut pos)?;
        // the first gid travels absolute; later entries add delta + 1
        // since the source list is strictly increasing
        let g = prev
            .checked_add(delta)
            .and_then(|v| v.checked_add(u64::from(i > 0)))
            .ok_or(CodecError::ValueOverflow)?;
        if g > u32::MAX as u64 {
            return Err(CodecError::ValueOverflow);
        }
        gids.push(g as Gid);
        prev = g;
    }
    if pos != buf.len() {
        return Err(CodecError::LengthMismatch {
            declared: n,
            used: pos,
            len: buf.len(),
        });
    }
    Ok(gids)
}

/// Pack one window's spikes: sorted by (step, gid), step stored as
/// offset from `window_start`, gids delta-encoded per step group.
/// Errors with [`CodecError::SpikeBeforeWindow`] if any spike predates
/// the window — that would underflow the step offset and poison the
/// whole packet.
pub fn pack(
    window_start: u32,
    spikes: &[SpikeMsg],
) -> Result<Vec<u8>, CodecError> {
    let mut out = Vec::with_capacity(spikes.len() + 8);
    pack_into(window_start, spikes, &mut out)?;
    Ok(out)
}

/// [`pack`] appending to an existing buffer (frame assembly).
fn pack_into(
    window_start: u32,
    spikes: &[SpikeMsg],
    out: &mut Vec<u8>,
) -> Result<(), CodecError> {
    let mut sorted: Vec<(u32, u32)> =
        spikes.iter().map(|m| (m.step, m.gid)).collect();
    sorted.sort_unstable();
    if let Some(&(step, _)) = sorted.first() {
        if step < window_start {
            return Err(CodecError::SpikeBeforeWindow {
                step,
                window_start,
            });
        }
    }
    put_varint(out, sorted.len() as u64);
    let mut prev_step = window_start;
    let mut prev_gid = 0u32;
    for (step, gid) in sorted {
        let dstep = step - prev_step;
        put_varint(out, dstep as u64);
        if dstep > 0 {
            prev_gid = 0; // gid deltas restart per step group
        }
        put_varint(out, (gid - prev_gid) as u64);
        prev_step = step;
        prev_gid = gid;
    }
    Ok(())
}

/// Unpack (inverse of [`pack`]). Fully fallible: truncated buffers,
/// overlong varints, deltas escaping the 32-bit domain and packets
/// whose declared spike count disagrees with the frame length are all
/// [`CodecError`]s, never panics.
pub fn unpack(
    window_start: u32,
    buf: &[u8],
) -> Result<SpikePacket, CodecError> {
    let mut pos = 0usize;
    let out = unpack_at(window_start, buf, &mut pos)?;
    if pos != buf.len() {
        return Err(CodecError::LengthMismatch {
            declared: out.len() as u64,
            used: pos,
            len: buf.len(),
        });
    }
    Ok(out)
}

/// Decode a packed spike list starting at `*pos`; advances `pos` past
/// it (does not require it to reach the end of `buf`).
fn unpack_at(
    window_start: u32,
    buf: &[u8],
    pos: &mut usize,
) -> Result<SpikePacket, CodecError> {
    let n = get_varint(buf, pos)?;
    // every spike costs at least 2 bytes (one varint each for dstep and
    // dgid) — a declared count beyond that bound can never be satisfied,
    // so reject it before allocating anything proportional to it
    let remaining = (buf.len() - *pos) as u64;
    if n.saturating_mul(2) > remaining {
        return Err(CodecError::Truncated);
    }
    let mut out = Vec::with_capacity(n as usize);
    let mut step = window_start as u64;
    let mut gid = 0u64;
    for _ in 0..n {
        let dstep = get_varint(buf, pos)?;
        step = step
            .checked_add(dstep)
            .ok_or(CodecError::ValueOverflow)?;
        if step > u32::MAX as u64 {
            return Err(CodecError::ValueOverflow);
        }
        if dstep > 0 {
            gid = 0;
        }
        let dgid = get_varint(buf, pos)?;
        gid =
            gid.checked_add(dgid).ok_or(CodecError::ValueOverflow)?;
        if gid > u32::MAX as u64 {
            return Err(CodecError::ValueOverflow);
        }
        out.push(SpikeMsg { gid: gid as u32, step: step as u32 });
    }
    Ok(out)
}

/// Encode one window-exchange frame payload (the unit the TCP transport
/// length-prefixes): varint window counter, varint window start, packed
/// spikes. The window start is derived from the packet itself (minimum
/// spike step; 0 when empty), so frames are self-describing and
/// independent of the receiver's step bookkeeping.
pub fn encode_frame(
    window: u64,
    spikes: &[SpikeMsg],
) -> Result<Vec<u8>, CodecError> {
    let start = spikes.iter().map(|m| m.step).min().unwrap_or(0);
    let mut out = Vec::with_capacity(spikes.len() + 16);
    put_varint(&mut out, window);
    put_varint(&mut out, start as u64);
    pack_into(start, spikes, &mut out)?;
    Ok(out)
}

/// Decode a frame payload produced by [`encode_frame`]: returns the
/// embedded window counter and the spikes. The caller is responsible
/// for checking the counter against its own window position (see
/// [`crate::comm::CommError::WindowMismatch`]).
pub fn decode_frame(
    buf: &[u8],
) -> Result<(u64, SpikePacket), CodecError> {
    let mut pos = 0usize;
    let window = get_varint(buf, &mut pos)?;
    let start = get_varint(buf, &mut pos)?;
    if start > u32::MAX as u64 {
        return Err(CodecError::ValueOverflow);
    }
    let spikes = unpack_at(start as u32, buf, &mut pos)?;
    if pos != buf.len() {
        return Err(CodecError::LengthMismatch {
            declared: spikes.len() as u64,
            used: pos,
            len: buf.len(),
        });
    }
    Ok((window, spikes))
}

/// One (source rank, destination rank) sub-frame inside a merged
/// multi-source container ([`encode_merged`]). The hierarchical
/// exchange moves these through relay ranks; the final receiver sorts
/// its entries by `source` so concatenation reproduces the flat routed
/// exchange's source-rank delivery order bit-for-bit.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergedEntry {
    pub source: u16,
    pub dest: u16,
    pub spikes: SpikePacket,
}

/// Encode a merged multi-source frame: varint window counter, varint
/// entry count, then per entry varint source rank, varint destination
/// rank, varint window start (minimum spike step, self-describing like
/// [`encode_frame`]) and the packed spike list. One such frame replaces
/// a whole group's per-peer frames on the inter-group wire, which is
/// where the hierarchical exchange sheds its message count.
///
/// The assembled frame is bounded against `limit` (the transport's
/// frame cap): a merge that would exceed it is refused with
/// [`CodecError::Oversize`] instead of poisoning the receiving peer.
pub fn encode_merged(
    window: u64,
    entries: &[MergedEntry],
    limit: usize,
) -> Result<Vec<u8>, CodecError> {
    let mut out = Vec::with_capacity(
        16 + entries.iter().map(|e| e.spikes.len() + 8).sum::<usize>(),
    );
    put_varint(&mut out, window);
    put_varint(&mut out, entries.len() as u64);
    for e in entries {
        put_varint(&mut out, e.source as u64);
        put_varint(&mut out, e.dest as u64);
        let start =
            e.spikes.iter().map(|m| m.step).min().unwrap_or(0);
        put_varint(&mut out, start as u64);
        pack_into(start, &e.spikes, &mut out)?;
    }
    if out.len() > limit {
        return Err(CodecError::Oversize {
            bytes: out.len(),
            limit,
        });
    }
    Ok(out)
}

/// Decode a merged multi-source frame produced by [`encode_merged`]:
/// returns the embedded window counter and the sub-frame entries in
/// wire order. Fully fallible like the rest of the codec — truncated
/// buffers, overlong varints, ranks escaping the 16-bit domain,
/// implausible entry counts and trailing bytes are all [`CodecError`]s,
/// never panics. Rank-topology checks (does `source` belong to the
/// sending group, is `dest` local) stay with the caller, which knows
/// the group layout.
pub fn decode_merged(
    buf: &[u8],
) -> Result<(u64, Vec<MergedEntry>), CodecError> {
    let mut pos = 0usize;
    let window = get_varint(buf, &mut pos)?;
    let n = get_varint(buf, &mut pos)?;
    // every entry costs at least 4 bytes (source, dest, start, spike
    // count — one varint each); reject counts the buffer cannot hold
    // before allocating anything proportional to them
    let remaining = (buf.len() - pos) as u64;
    if n.saturating_mul(4) > remaining {
        return Err(CodecError::Truncated);
    }
    let mut entries = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let source = get_varint(buf, &mut pos)?;
        let dest = get_varint(buf, &mut pos)?;
        if source > u16::MAX as u64 || dest > u16::MAX as u64 {
            return Err(CodecError::ValueOverflow);
        }
        let start = get_varint(buf, &mut pos)?;
        if start > u32::MAX as u64 {
            return Err(CodecError::ValueOverflow);
        }
        let spikes = unpack_at(start as u32, buf, &mut pos)?;
        entries.push(MergedEntry {
            source: source as u16,
            dest: dest as u16,
            spikes,
        });
    }
    if pos != buf.len() {
        return Err(CodecError::LengthMismatch {
            declared: n,
            used: pos,
            len: buf.len(),
        });
    }
    Ok((window, entries))
}

/// Message-count/volume model of one window exchange among `ranks`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExchangePlan {
    /// messages each rank sends
    pub messages_per_rank: f64,
    /// total bytes each rank sends
    pub bytes_per_rank: f64,
    /// dissemination stages (1 = direct)
    pub stages: u32,
    pub routed: bool,
}

/// BSB's adaptive choice (the "adaptively routes ... to decrease the
/// number of small messages"): with per-peer payload below
/// `route_threshold_bytes`, use a radix-k dissemination tree (k·log_k R
/// aggregated messages, each carrying ~R/k ranks' packed spikes);
/// otherwise exchange directly.
pub fn plan_exchange(
    ranks: usize,
    packed_bytes: f64,
    radix: u32,
    route_threshold_bytes: f64,
) -> ExchangePlan {
    assert!(ranks >= 1 && radix >= 2);
    if ranks == 1 {
        return ExchangePlan {
            messages_per_rank: 0.0,
            bytes_per_rank: 0.0,
            stages: 0,
            routed: false,
        };
    }
    let r = ranks as f64;
    if packed_bytes >= route_threshold_bytes {
        // dense: direct allgather of the packed payload
        ExchangePlan {
            messages_per_rank: r - 1.0,
            bytes_per_rank: packed_bytes * (r - 1.0),
            stages: 1,
            routed: false,
        }
    } else {
        // sparse: radix-k dissemination — log_k(R) stages, k-1 messages
        // per stage, message s carrying the payloads accumulated so far
        let stages = (r.ln() / (radix as f64).ln()).ceil() as u32;
        let k = radix as f64 - 1.0;
        // accumulated payload grows by radix each stage:
        // sum_{s=0}^{stages-1} (k) * packed * radix^s
        let mut bytes = 0.0;
        let mut acc = packed_bytes;
        for _ in 0..stages {
            bytes += k * acc;
            acc *= radix as f64;
        }
        ExchangePlan {
            messages_per_rank: k * stages as f64,
            bytes_per_rank: bytes,
            stages,
            routed: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn window(rng: &mut Rng, start: u32, len: u32, n: usize) -> SpikePacket {
        (0..n)
            .map(|_| SpikeMsg {
                gid: rng.below(100_000) as u32,
                step: start + rng.below(len as u64) as u32,
            })
            .collect()
    }

    #[test]
    fn gid_list_roundtrips_and_stays_total() {
        let mut rng = Rng::new(11);
        for _ in 0..200 {
            let n = rng.below(300) as usize;
            let mut gids: Vec<Gid> = (0..n)
                .map(|_| rng.below(u32::MAX as u64 + 1) as Gid)
                .collect();
            gids.sort_unstable();
            gids.dedup();
            let buf = encode_gid_list(&gids);
            assert_eq!(decode_gid_list(&buf).unwrap(), gids);
            // dense runs (the common subscription shape) stay compact
            if gids.is_empty() {
                assert_eq!(buf.len(), 1);
            }
            // every strict prefix must error, never panic
            for cut in 0..buf.len() {
                assert!(decode_gid_list(&buf[..cut]).is_err());
            }
        }
        // a consecutive run costs one byte per gid after the first
        let run: Vec<Gid> = (1000..2000).collect();
        let buf = encode_gid_list(&run);
        assert!(buf.len() <= run.len() + 3, "{} bytes", buf.len());
        // trailing garbage is rejected
        let mut buf = encode_gid_list(&[3, 5, 9]);
        buf.push(0);
        assert!(decode_gid_list(&buf).is_err());
        // a delta pushing past u32::MAX is rejected
        let mut buf = Vec::new();
        put_varint(&mut buf, 2);
        put_varint(&mut buf, u32::MAX as u64);
        put_varint(&mut buf, 1);
        assert_eq!(
            decode_gid_list(&buf),
            Err(CodecError::ValueOverflow)
        );
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let values = [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX];
        for &v in &values {
            put_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &values {
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn varint_truncation_and_overflow_are_errors() {
        // ends mid-varint
        let mut pos = 0;
        assert_eq!(
            get_varint(&[0x80, 0x80], &mut pos),
            Err(CodecError::Truncated)
        );
        // 10 continuation bytes: shifted past 63 bits
        let mut pos = 0;
        assert_eq!(
            get_varint(&[0xff; 11], &mut pos),
            Err(CodecError::VarintOverflow)
        );
        // 10th byte may carry only the final bit
        let mut buf = vec![0x80u8; 9];
        buf.push(0x01);
        let mut pos = 0;
        assert_eq!(get_varint(&buf, &mut pos).unwrap(), 1u64 << 63);
        let mut buf = vec![0x80u8; 9];
        buf.push(0x02);
        let mut pos = 0;
        assert_eq!(
            get_varint(&buf, &mut pos),
            Err(CodecError::VarintOverflow)
        );
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(5);
        for case in 0..50 {
            let start = case * 20;
            let spikes = window(&mut rng, start, 15, (case % 7) as usize * 13);
            let buf = pack(start, &spikes).unwrap();
            let mut got = unpack(start, &buf).unwrap();
            let mut want = spikes.clone();
            want.sort_unstable_by_key(|m| (m.step, m.gid));
            got.sort_unstable_by_key(|m| (m.step, m.gid));
            assert_eq!(got, want, "case {case}");
        }
    }

    #[test]
    fn frame_roundtrip_carries_the_window_counter() {
        let mut rng = Rng::new(11);
        for w in 0..20u64 {
            let start = (w * 15) as u32 + 3;
            let spikes = window(&mut rng, start, 15, (w % 5) as usize * 9);
            let frame = encode_frame(w, &spikes).unwrap();
            let (got_w, mut got) = decode_frame(&frame).unwrap();
            assert_eq!(got_w, w);
            let mut want = spikes.clone();
            want.sort_unstable_by_key(|m| (m.step, m.gid));
            got.sort_unstable_by_key(|m| (m.step, m.gid));
            assert_eq!(got, want, "window {w}");
        }
    }

    #[test]
    fn pack_rejects_spike_before_window() {
        let spikes = [SpikeMsg { gid: 1, step: 50 }];
        assert_eq!(
            pack(100, &spikes),
            Err(CodecError::SpikeBeforeWindow {
                step: 50,
                window_start: 100
            })
        );
    }

    #[test]
    fn unpack_rejects_trailing_bytes() {
        let spikes = [SpikeMsg { gid: 3, step: 8 }];
        let mut buf = pack(8, &spikes).unwrap();
        buf.push(0x00);
        assert!(matches!(
            unpack(8, &buf),
            Err(CodecError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn unpack_rejects_absurd_spike_count() {
        // declares u64::MAX spikes in a 1-byte body: must error without
        // attempting the allocation
        let mut buf = Vec::new();
        put_varint(&mut buf, u64::MAX);
        buf.push(0x00);
        assert_eq!(unpack(0, &buf), Err(CodecError::Truncated));
    }

    #[test]
    fn unpack_rejects_value_overflow() {
        // one spike whose dstep overflows u32 from window_start 0
        let mut buf = Vec::new();
        put_varint(&mut buf, 1);
        put_varint(&mut buf, (u32::MAX as u64) + 1);
        put_varint(&mut buf, 0);
        assert_eq!(unpack(0, &buf), Err(CodecError::ValueOverflow));
    }

    #[test]
    fn packing_beats_naive_wire_format() {
        let mut rng = Rng::new(9);
        // dense-ish window: 2000 spikes from 100k neurons over 15 steps
        let spikes = window(&mut rng, 1000, 15, 2000);
        let packed = pack(1000, &spikes).unwrap().len() as f64;
        let naive = (spikes.len() * 8) as f64;
        assert!(
            packed < 0.5 * naive,
            "packed {packed} vs naive {naive} — expected >2x compression"
        );
    }

    #[test]
    fn empty_window() {
        let buf = pack(7, &[]).unwrap();
        assert!(buf.len() <= 2);
        assert!(unpack(7, &buf).unwrap().is_empty());
        let frame = encode_frame(42, &[]).unwrap();
        let (w, spikes) = decode_frame(&frame).unwrap();
        assert_eq!(w, 42);
        assert!(spikes.is_empty());
    }

    #[test]
    fn merged_frame_roundtrips() {
        let mut rng = Rng::new(17);
        for w in 0..30u64 {
            let n_entries = (w % 5) as usize;
            let entries: Vec<MergedEntry> = (0..n_entries)
                .map(|i| MergedEntry {
                    source: (i * 2) as u16,
                    dest: (i * 2 + 1) as u16,
                    spikes: window(
                        &mut rng,
                        (w * 15) as u32,
                        15,
                        (i * 7) % 23,
                    ),
                })
                .collect();
            let buf =
                encode_merged(w, &entries, usize::MAX).unwrap();
            let (got_w, got) = decode_merged(&buf).unwrap();
            assert_eq!(got_w, w);
            assert_eq!(got.len(), entries.len());
            for (g, e) in got.iter().zip(&entries) {
                assert_eq!((g.source, g.dest), (e.source, e.dest));
                let mut want = e.spikes.clone();
                want.sort_unstable_by_key(|m| (m.step, m.gid));
                let mut have = g.spikes.clone();
                have.sort_unstable_by_key(|m| (m.step, m.gid));
                assert_eq!(have, want);
            }
        }
    }

    #[test]
    fn merged_frame_respects_the_size_bound() {
        let entries = vec![MergedEntry {
            source: 0,
            dest: 1,
            spikes: (0..1000)
                .map(|i| SpikeMsg { gid: i * 3, step: 5 })
                .collect(),
        }];
        let full = encode_merged(3, &entries, usize::MAX).unwrap();
        assert_eq!(
            encode_merged(3, &entries, full.len()).unwrap().len(),
            full.len()
        );
        assert_eq!(
            encode_merged(3, &entries, full.len() - 1),
            Err(CodecError::Oversize {
                bytes: full.len(),
                limit: full.len() - 1
            })
        );
    }

    #[test]
    fn merged_frame_rejects_absurd_entry_count() {
        let mut buf = Vec::new();
        put_varint(&mut buf, 9); // window
        put_varint(&mut buf, u64::MAX); // entries
        buf.push(0);
        assert_eq!(decode_merged(&buf), Err(CodecError::Truncated));
    }

    #[test]
    fn merged_frame_rejects_rank_overflow_and_trailing_bytes() {
        // source rank past u16
        let mut buf = Vec::new();
        put_varint(&mut buf, 0); // window
        put_varint(&mut buf, 1); // one entry
        put_varint(&mut buf, (u16::MAX as u64) + 1);
        put_varint(&mut buf, 0);
        put_varint(&mut buf, 0);
        put_varint(&mut buf, 0);
        assert_eq!(
            decode_merged(&buf),
            Err(CodecError::ValueOverflow)
        );
        // trailing garbage after a valid frame
        let mut buf = encode_merged(
            1,
            &[MergedEntry {
                source: 2,
                dest: 3,
                spikes: vec![SpikeMsg { gid: 4, step: 20 }],
            }],
            usize::MAX,
        )
        .unwrap();
        buf.push(0);
        assert!(matches!(
            decode_merged(&buf),
            Err(CodecError::LengthMismatch { .. })
        ));
    }

    #[test]
    fn merged_decode_never_panics_on_adversarial_bytes() {
        // bit-flip and truncation fuzz over a real frame — every decode
        // must return, never panic (the container is wire input)
        let mut rng = Rng::new(41);
        let entries: Vec<MergedEntry> = (0..4)
            .map(|i| MergedEntry {
                source: i,
                dest: 7 - i,
                spikes: window(&mut rng, 100, 15, 40),
            })
            .collect();
        let frame = encode_merged(5, &entries, usize::MAX).unwrap();
        for cut in 0..frame.len() {
            let _ = decode_merged(&frame[..cut]);
        }
        for _ in 0..2000 {
            let mut fuzz = frame.clone();
            for _ in 0..1 + rng.below(4) {
                let i = rng.below(fuzz.len() as u64) as usize;
                fuzz[i] ^= 1 << rng.below(8);
            }
            let _ = decode_merged(&fuzz);
        }
        for _ in 0..500 {
            let len = rng.below(64) as usize;
            let junk: Vec<u8> =
                (0..len).map(|_| rng.below(256) as u8).collect();
            let _ = decode_merged(&junk);
        }
    }

    #[test]
    fn plan_sparse_routes_dense_goes_direct() {
        let sparse = plan_exchange(1024, 64.0, 4, 4096.0);
        assert!(sparse.routed);
        assert_eq!(sparse.stages, 5); // log4(1024)
        assert_eq!(sparse.messages_per_rank, 15.0); // 3 per stage
        let dense = plan_exchange(1024, (1u64 << 20) as f64, 4, 4096.0);
        assert!(!dense.routed);
        assert_eq!(dense.messages_per_rank, 1023.0);
    }

    #[test]
    fn routed_message_count_far_below_direct() {
        for ranks in [64usize, 1024, 16384] {
            let p = plan_exchange(ranks, 100.0, 8, 1e6);
            assert!(p.routed);
            assert!(
                p.messages_per_rank < 0.05 * ranks as f64 + 30.0,
                "{ranks} ranks: {} msgs",
                p.messages_per_rank
            );
        }
    }

    #[test]
    fn single_rank_plan_is_empty() {
        let p = plan_exchange(1, 100.0, 4, 1e3);
        assert_eq!(p.messages_per_rank, 0.0);
    }
}
