//! In-memory cluster: ranks are threads, the transport is a full mesh of
//! FIFO channels. `exchange` = send-to-all + receive-from-all, the same
//! collective the paper's Spikes Broadcast performs over MPI.
//!
//! Window alignment is structural: each rank sends exactly one packet per
//! window to every peer and channels are FIFO per (src, dst) pair, so the
//! k-th receive from a peer is always that peer's window-k packet. The
//! embedded window counter is nevertheless **verified on every receive**
//! — in release builds too — and a mismatch is a returned
//! [`CommError::WindowMismatch`], not a silently consumed stale packet;
//! the TCP transport relies on the same contract across real sockets.

use std::sync::mpsc::{channel, Receiver, Sender};

use super::{CommError, Communicator, SpikePacket, SPIKE_WIRE_BYTES};

struct Packet {
    window: u64,
    spikes: SpikePacket,
}

/// One rank's endpoint of the cluster.
pub struct LocalComm {
    rank: u16,
    size: usize,
    /// senders to every peer (self slot unused).
    to_peer: Vec<Option<Sender<Packet>>>,
    /// receivers from every peer (self slot unused).
    from_peer: Vec<Option<Receiver<Packet>>>,
    window: u64,
    bytes_sent: u64,
}

/// Factory for a set of wired endpoints.
pub struct LocalCluster;

impl LocalCluster {
    /// Create `n` fully-connected endpoints.
    pub fn new(n: usize) -> Vec<LocalComm> {
        assert!(n >= 1);
        // channels[src][dst]
        let mut senders: Vec<Vec<Option<Sender<Packet>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Option<Receiver<Packet>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                let (tx, rx) = channel();
                senders[src][dst] = Some(tx);
                receivers[dst][src] = Some(rx);
            }
        }
        senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (to_peer, from_peer))| LocalComm {
                rank: rank as u16,
                size: n,
                to_peer,
                from_peer,
                window: 0,
                bytes_sent: 0,
            })
            .collect()
    }
}

impl Communicator for LocalComm {
    fn rank(&self) -> u16 {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn exchange(
        &mut self,
        local: SpikePacket,
    ) -> Result<SpikePacket, CommError> {
        let window = self.window;
        self.window += 1;
        // broadcast to all peers
        for dst in 0..self.size {
            if let Some(tx) = &self.to_peer[dst] {
                self.bytes_sent +=
                    local.len() as u64 * SPIKE_WIRE_BYTES;
                // peer hung up (e.g. errored out): ignore here, the
                // receive below reports the lost peer
                let _ = tx.send(Packet { window, spikes: local.clone() });
            }
        }
        // gather from all peers
        let mut all = Vec::new();
        for src in 0..self.size {
            if let Some(rx) = &self.from_peer[src] {
                match rx.recv() {
                    Ok(p) => {
                        if p.window != window {
                            return Err(CommError::WindowMismatch {
                                got: p.window,
                                want: window,
                            });
                        }
                        all.extend(p.spikes);
                    }
                    Err(_) => {
                        return Err(CommError::PeerLost {
                            peer: src as u16,
                            window,
                        })
                    }
                }
            }
        }
        Ok(all)
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn exchanges(&self) -> u64 {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SpikeMsg;
    use std::thread;

    #[test]
    fn allgather_three_ranks() {
        let comms = LocalCluster::new(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mine = vec![SpikeMsg {
                        gid: c.rank() as u32 * 10,
                        step: 1,
                    }];
                    let mut got = c.exchange(mine).unwrap();
                    got.sort_by_key(|m| m.gid);
                    got
                })
            })
            .collect();
        for (r, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            // each rank receives the other two ranks' spikes
            assert_eq!(got.len(), 2);
            assert!(got.iter().all(|m| m.gid != r as u32 * 10));
        }
    }

    #[test]
    fn multiple_windows_stay_aligned() {
        let comms = LocalCluster::new(2);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mut sums = Vec::new();
                    for w in 0..50u32 {
                        let mine = vec![SpikeMsg {
                            gid: c.rank() as u32,
                            step: w,
                        }];
                        let got = c.exchange(mine).unwrap();
                        sums.push(got[0].step);
                    }
                    sums
                })
            })
            .collect();
        for h in handles {
            let sums = h.join().unwrap();
            assert_eq!(sums, (0..50).collect::<Vec<u32>>());
        }
    }

    #[test]
    fn byte_accounting() {
        let comms = LocalCluster::new(2);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let spikes = vec![SpikeMsg { gid: 0, step: 0 }; 5];
                    c.exchange(spikes).unwrap();
                    c.bytes_sent()
                })
            })
            .collect();
        for h in handles {
            // 5 spikes × 8 bytes × 1 peer
            assert_eq!(h.join().unwrap(), 40);
        }
    }

    #[test]
    fn lost_peer_is_an_error_not_a_panic() {
        let mut comms = LocalCluster::new(2);
        let b = comms.pop().unwrap();
        let mut a = comms.pop().unwrap();
        drop(b); // peer 1 is gone before the first window
        let err = a.exchange(Vec::new()).unwrap_err();
        assert!(
            matches!(err, CommError::PeerLost { peer: 1, window: 0 }),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn single_rank_cluster_is_trivial() {
        let mut comms = LocalCluster::new(1);
        let mut c = comms.pop().unwrap();
        assert!(c
            .exchange(vec![SpikeMsg { gid: 1, step: 0 }])
            .unwrap()
            .is_empty());
    }
}
