//! In-memory cluster: ranks are threads, the transport is a full mesh of
//! FIFO channels. `exchange` = send-to-all + receive-from-all, the same
//! collective the paper's Spikes Broadcast performs over MPI.
//!
//! Window alignment is structural: each rank sends exactly one packet per
//! window to every peer and channels are FIFO per (src, dst) pair, so the
//! k-th receive from a peer is always that peer's window-k packet. The
//! embedded window counter is nevertheless **verified on every receive**
//! — in release builds too — and a mismatch is a returned
//! [`CommError::WindowMismatch`], not a silently consumed stale packet;
//! the TCP transport relies on the same contract across real sockets.

use std::sync::mpsc::{channel, Receiver, Sender};

use super::{
    CommError, Communicator, Outbound, SpikePacket, SPIKE_WIRE_BYTES,
};

/// One channel message: a window's spikes, or a build-time blob of the
/// subscription collective ([`Communicator::alltoall`]).
enum Packet {
    Spikes { window: u64, spikes: SpikePacket },
    Blob(Vec<u8>),
}

/// One rank's endpoint of the cluster.
pub struct LocalComm {
    rank: u16,
    size: usize,
    /// senders to every peer (self slot unused).
    to_peer: Vec<Option<Sender<Packet>>>,
    /// receivers from every peer (self slot unused).
    from_peer: Vec<Option<Receiver<Packet>>>,
    window: u64,
    bytes_sent: u64,
    bytes_received: u64,
}

/// Factory for a set of wired endpoints.
pub struct LocalCluster;

impl LocalCluster {
    /// Create `n` fully-connected endpoints.
    pub fn new(n: usize) -> Vec<LocalComm> {
        assert!(n >= 1);
        // channels[src][dst]
        let mut senders: Vec<Vec<Option<Sender<Packet>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Option<Receiver<Packet>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                let (tx, rx) = channel();
                senders[src][dst] = Some(tx);
                receivers[dst][src] = Some(rx);
            }
        }
        senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (to_peer, from_peer))| LocalComm {
                rank: rank as u16,
                size: n,
                to_peer,
                from_peer,
                window: 0,
                bytes_sent: 0,
                bytes_received: 0,
            })
            .collect()
    }
}

impl Communicator for LocalComm {
    fn rank(&self) -> u16 {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn exchange_outbound(
        &mut self,
        out: Outbound,
    ) -> Result<SpikePacket, CommError> {
        let window = self.window;
        self.window += 1;
        // send each peer its packet: the shared broadcast packet is
        // cloned per peer, routed packets are moved out of their slots
        let (bcast, mut per) = match out {
            Outbound::Broadcast(p) => (Some(p), Vec::new()),
            Outbound::Routed(per) => {
                assert_eq!(per.len(), self.size, "one packet per rank");
                (None, per)
            }
        };
        for dst in 0..self.size {
            if let Some(tx) = &self.to_peer[dst] {
                let spikes = match &bcast {
                    Some(p) => p.clone(),
                    None => std::mem::take(&mut per[dst]),
                };
                self.bytes_sent +=
                    spikes.len() as u64 * SPIKE_WIRE_BYTES;
                // peer hung up (e.g. errored out): ignore here, the
                // receive below reports the lost peer
                let _ = tx.send(Packet::Spikes { window, spikes });
            }
        }
        // gather from all peers
        let mut all = Vec::new();
        for src in 0..self.size {
            if let Some(rx) = &self.from_peer[src] {
                match rx.recv() {
                    Ok(Packet::Spikes { window: w, spikes }) => {
                        if w != window {
                            return Err(CommError::WindowMismatch {
                                got: w,
                                want: window,
                            });
                        }
                        self.bytes_received +=
                            spikes.len() as u64 * SPIKE_WIRE_BYTES;
                        all.extend(spikes);
                    }
                    Ok(Packet::Blob(_)) => {
                        return Err(CommError::Protocol(
                            "subscription blob during a spike window",
                        ))
                    }
                    Err(_) => {
                        return Err(CommError::PeerLost {
                            peer: src as u16,
                            window,
                        })
                    }
                }
            }
        }
        Ok(all)
    }

    fn alltoall(
        &mut self,
        out: Vec<Vec<u8>>,
    ) -> Result<Vec<Vec<u8>>, CommError> {
        assert_eq!(out.len(), self.size, "one blob per rank");
        let mut blobs = out;
        for (dst, blob) in blobs.iter_mut().enumerate() {
            if let Some(tx) = &self.to_peer[dst] {
                let _ = tx.send(Packet::Blob(std::mem::take(blob)));
            }
        }
        let mut got = vec![Vec::new(); self.size];
        for src in 0..self.size {
            if let Some(rx) = &self.from_peer[src] {
                match rx.recv() {
                    Ok(Packet::Blob(b)) => got[src] = b,
                    Ok(Packet::Spikes { .. }) => {
                        return Err(CommError::Protocol(
                            "spike packet during the subscription \
                             collective",
                        ))
                    }
                    Err(_) => {
                        return Err(CommError::PeerLost {
                            peer: src as u16,
                            window: self.window,
                        })
                    }
                }
            }
        }
        Ok(got)
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn bytes_received(&self) -> u64 {
        self.bytes_received
    }

    fn exchanges(&self) -> u64 {
        self.window
    }

    fn send_frame(
        &mut self,
        peer: usize,
        payload: &[u8],
    ) -> Result<(), CommError> {
        let tx = self
            .to_peer
            .get(peer)
            .and_then(|t| t.as_ref())
            .ok_or(CommError::Protocol(
                "point-to-point frame addressed to a non-peer",
            ))?;
        self.bytes_sent += payload.len() as u64;
        tx.send(Packet::Blob(payload.to_vec())).map_err(|_| {
            CommError::PeerLost {
                peer: peer as u16,
                window: self.window,
            }
        })
    }

    fn recv_frame(&mut self, peer: usize) -> Result<Vec<u8>, CommError> {
        let rx = self
            .from_peer
            .get(peer)
            .and_then(|r| r.as_ref())
            .ok_or(CommError::Protocol(
                "point-to-point frame expected from a non-peer",
            ))?;
        match rx.recv() {
            Ok(Packet::Blob(b)) => {
                self.bytes_received += b.len() as u64;
                Ok(b)
            }
            Ok(Packet::Spikes { .. }) => Err(CommError::Protocol(
                "spike packet where a relay frame was due",
            )),
            Err(_) => Err(CommError::PeerLost {
                peer: peer as u16,
                window: self.window,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SpikeMsg;
    use std::thread;

    #[test]
    fn allgather_three_ranks() {
        let comms = LocalCluster::new(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mine = vec![SpikeMsg {
                        gid: c.rank() as u32 * 10,
                        step: 1,
                    }];
                    let mut got = c.exchange(mine).unwrap();
                    got.sort_by_key(|m| m.gid);
                    got
                })
            })
            .collect();
        for (r, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            // each rank receives the other two ranks' spikes
            assert_eq!(got.len(), 2);
            assert!(got.iter().all(|m| m.gid != r as u32 * 10));
        }
    }

    #[test]
    fn multiple_windows_stay_aligned() {
        let comms = LocalCluster::new(2);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mut sums = Vec::new();
                    for w in 0..50u32 {
                        let mine = vec![SpikeMsg {
                            gid: c.rank() as u32,
                            step: w,
                        }];
                        let got = c.exchange(mine).unwrap();
                        sums.push(got[0].step);
                    }
                    sums
                })
            })
            .collect();
        for h in handles {
            let sums = h.join().unwrap();
            assert_eq!(sums, (0..50).collect::<Vec<u32>>());
        }
    }

    #[test]
    fn byte_accounting() {
        let comms = LocalCluster::new(2);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let spikes = vec![SpikeMsg { gid: 0, step: 0 }; 5];
                    c.exchange(spikes).unwrap();
                    (c.bytes_sent(), c.bytes_received())
                })
            })
            .collect();
        for h in handles {
            // 5 spikes × 8 bytes × 1 peer, both directions
            assert_eq!(h.join().unwrap(), (40, 40));
        }
    }

    #[test]
    fn routed_exchange_delivers_only_the_targeted_packets() {
        let comms = LocalCluster::new(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let r = c.rank() as u32;
                    // rank r sends gid 100*r+dst to each dst
                    let per: Vec<SpikePacket> = (0..3)
                        .map(|dst| {
                            vec![SpikeMsg {
                                gid: 100 * r + dst,
                                step: 0,
                            }]
                        })
                        .collect();
                    let got = c
                        .exchange_outbound(Outbound::Routed(per))
                        .unwrap();
                    (r, got, c.bytes_sent(), c.bytes_received())
                })
            })
            .collect();
        for h in handles {
            let (r, got, sent, received) = h.join().unwrap();
            // source-rank order, exactly the packets addressed to r
            let want: Vec<SpikeMsg> = (0..3)
                .filter(|&src| src != r)
                .map(|src| SpikeMsg { gid: 100 * src + r, step: 0 })
                .collect();
            assert_eq!(got, want, "rank {r}");
            // 1 spike × 8 bytes × 2 peers, both directions
            assert_eq!((sent, received), (16, 16), "rank {r}");
        }
    }

    #[test]
    fn alltoall_ships_each_blob_to_its_addressee() {
        let comms = LocalCluster::new(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let r = c.rank();
                    let out: Vec<Vec<u8>> =
                        (0..3).map(|d| vec![r as u8, d as u8]).collect();
                    let got = c.alltoall(out).unwrap();
                    // a window exchange still works afterwards (the
                    // collective must not disturb the window counter)
                    let spikes = c.exchange(Vec::new()).unwrap();
                    assert!(spikes.is_empty());
                    assert_eq!(c.exchanges(), 1);
                    (r, got)
                })
            })
            .collect();
        for h in handles {
            let (r, got) = h.join().unwrap();
            for src in 0..3u8 {
                if src == r as u8 {
                    assert!(got[src as usize].is_empty());
                } else {
                    assert_eq!(got[src as usize], vec![src, r as u8]);
                }
            }
        }
    }

    #[test]
    fn lost_peer_is_an_error_not_a_panic() {
        let mut comms = LocalCluster::new(2);
        let b = comms.pop().unwrap();
        let mut a = comms.pop().unwrap();
        drop(b); // peer 1 is gone before the first window
        let err = a.exchange(Vec::new()).unwrap_err();
        assert!(
            matches!(err, CommError::PeerLost { peer: 1, window: 0 }),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn single_rank_cluster_is_trivial() {
        let mut comms = LocalCluster::new(1);
        let mut c = comms.pop().unwrap();
        assert!(c
            .exchange(vec![SpikeMsg { gid: 1, step: 0 }])
            .unwrap()
            .is_empty());
    }
}
