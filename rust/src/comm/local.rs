//! In-memory cluster: ranks are threads, the transport is a full mesh of
//! FIFO channels. `exchange` = send-to-all + receive-from-all, the same
//! collective the paper's Spikes Broadcast performs over MPI.
//!
//! Window alignment is structural: each rank sends exactly one packet per
//! window to every peer and channels are FIFO per (src, dst) pair, so the
//! k-th receive from a peer is always that peer's window-k packet (the
//! embedded window counter is asserted in debug builds).

use std::sync::mpsc::{channel, Receiver, Sender};

use super::{Communicator, SpikePacket, SPIKE_WIRE_BYTES};

struct Packet {
    window: u64,
    spikes: SpikePacket,
}

/// One rank's endpoint of the cluster.
pub struct LocalComm {
    rank: u16,
    size: usize,
    /// senders to every peer (self slot unused).
    to_peer: Vec<Option<Sender<Packet>>>,
    /// receivers from every peer (self slot unused).
    from_peer: Vec<Option<Receiver<Packet>>>,
    window: u64,
    bytes_sent: u64,
}

/// Factory for a set of wired endpoints.
pub struct LocalCluster;

impl LocalCluster {
    /// Create `n` fully-connected endpoints.
    pub fn new(n: usize) -> Vec<LocalComm> {
        assert!(n >= 1);
        // channels[src][dst]
        let mut senders: Vec<Vec<Option<Sender<Packet>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        let mut receivers: Vec<Vec<Option<Receiver<Packet>>>> =
            (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                let (tx, rx) = channel();
                senders[src][dst] = Some(tx);
                receivers[dst][src] = Some(rx);
            }
        }
        senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (to_peer, from_peer))| LocalComm {
                rank: rank as u16,
                size: n,
                to_peer,
                from_peer,
                window: 0,
                bytes_sent: 0,
            })
            .collect()
    }
}

impl Communicator for LocalComm {
    fn rank(&self) -> u16 {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn exchange(&mut self, local: SpikePacket) -> SpikePacket {
        let window = self.window;
        self.window += 1;
        // broadcast to all peers
        for dst in 0..self.size {
            if let Some(tx) = &self.to_peer[dst] {
                self.bytes_sent +=
                    local.len() as u64 * SPIKE_WIRE_BYTES;
                // peer hung up (e.g. panicked): ignore, the join will
                // surface the real error
                let _ = tx.send(Packet { window, spikes: local.clone() });
            }
        }
        // gather from all peers
        let mut all = Vec::new();
        for src in 0..self.size {
            if let Some(rx) = &self.from_peer[src] {
                match rx.recv() {
                    Ok(p) => {
                        debug_assert_eq!(
                            p.window, window,
                            "window misalignment {} vs {}",
                            p.window, window
                        );
                        all.extend(p.spikes);
                    }
                    Err(_) => panic!(
                        "rank {} lost peer {src} during window {window}",
                        self.rank
                    ),
                }
            }
        }
        all
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn exchanges(&self) -> u64 {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SpikeMsg;
    use std::thread;

    #[test]
    fn allgather_three_ranks() {
        let comms = LocalCluster::new(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mine = vec![SpikeMsg {
                        gid: c.rank() as u32 * 10,
                        step: 1,
                    }];
                    let mut got = c.exchange(mine);
                    got.sort_by_key(|m| m.gid);
                    got
                })
            })
            .collect();
        for (r, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            // each rank receives the other two ranks' spikes
            assert_eq!(got.len(), 2);
            assert!(got.iter().all(|m| m.gid != r as u32 * 10));
        }
    }

    #[test]
    fn multiple_windows_stay_aligned() {
        let comms = LocalCluster::new(2);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mut sums = Vec::new();
                    for w in 0..50u32 {
                        let mine = vec![SpikeMsg {
                            gid: c.rank() as u32,
                            step: w,
                        }];
                        let got = c.exchange(mine);
                        sums.push(got[0].step);
                    }
                    sums
                })
            })
            .collect();
        for h in handles {
            let sums = h.join().unwrap();
            assert_eq!(sums, (0..50).collect::<Vec<u32>>());
        }
    }

    #[test]
    fn byte_accounting() {
        let comms = LocalCluster::new(2);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let spikes = vec![SpikeMsg { gid: 0, step: 0 }; 5];
                    c.exchange(spikes);
                    c.bytes_sent()
                })
            })
            .collect();
        for h in handles {
            // 5 spikes × 8 bytes × 1 peer
            assert_eq!(h.join().unwrap(), 40);
        }
    }

    #[test]
    fn single_rank_cluster_is_trivial() {
        let mut comms = LocalCluster::new(1);
        let mut c = comms.pop().unwrap();
        assert!(c.exchange(vec![SpikeMsg { gid: 1, step: 0 }]).is_empty());
    }
}
