//! TCP rank runtime: ranks are OS **processes**, the transport is a full
//! mesh of TCP streams, and the [`bsb`] packed format is the actual
//! on-the-wire protocol — the paper's Spikes Broadcast collective
//! carried over real sockets instead of in-memory channels.
//!
//! # Cluster formation
//!
//! Every rank knows the full rank-ordered address list (`peers[r]` is
//! rank r's listen address). Rank `i` binds `peers[i]`, dials every
//! lower rank (retrying until that peer's listener is up, bounded by a
//! deadline) and accepts one connection from every higher rank. Each
//! stream opens with a fixed 14-byte handshake — magic, wire version,
//! sender rank, cluster size — validated on both ends, so a stray or
//! mis-configured process is rejected before any simulation traffic.
//!
//! # Exchange protocol
//!
//! One `exchange` call sends one **length-prefixed frame** (4-byte LE
//! length, then a [`bsb::encode_frame`] payload: varint window counter,
//! varint window start, packed spikes) to every peer and blocks reading
//! exactly one frame back from each, concatenating payloads in rank
//! order — the same send-to-all / receive-from-all collective
//! [`super::local::LocalComm`] performs, with the same deterministic
//! concatenation order, so rasters are bit-identical across the two
//! transports. The embedded window counter is verified on **every**
//! receive; a stale frame, a truncated or bit-flipped payload, or an
//! oversized length prefix each surface as a [`CommError`] — never a
//! panic — and the endpoint is considered poisoned afterwards.
//!
//! Streams run with `TCP_NODELAY` (one small latency-critical frame per
//! window per peer, the paper's §III.C traffic shape). The exchange
//! itself is a **nonblocking, interleaved** per-peer loop: every stream
//! is switched to nonblocking mode and the rank round-robins partial
//! writes and partial reads across all peers until each send and each
//! receive completes. No peer's frame is waited on before another's, so
//! a slow peer cannot head-of-line-block the window, and a mesh of
//! mutually-writing ranks makes progress regardless of frame size —
//! the old write-all-then-read-all pattern (and its helper-thread
//! workaround for frames beyond the kernel socket buffers) is gone.
//! The same loop carries the build-time subscription collective
//! ([`Communicator::alltoall`]), whose frames are raw
//! [`bsb::encode_gid_list`] blobs at a fixed protocol position before
//! the first window.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::{bsb, CommError, Communicator, Outbound, SpikePacket};

/// Handshake magic: "CORTEXTC" as LE bytes.
const HANDSHAKE_MAGIC: u64 = 0x4354_5845_5452_4f43;

/// Bump when the frame layout changes; both ends must agree.
pub const WIRE_VERSION: u16 = 1;

/// Sanity bound on one frame's payload (64 MiB ≈ tens of millions of
/// packed spikes per window per rank — far beyond anything a real
/// window produces). A length prefix above this is treated as
/// corruption, not honored with an allocation. Shared with the
/// hierarchical relay, whose merged frames must fit the same cap.
pub use super::MAX_FRAME_BYTES;

/// Poll interval while dialing a peer that is not listening yet.
const RETRY_EVERY: Duration = Duration::from_millis(50);

/// Nonblocking exchange loop: after this many consecutive pass
/// iterations without a single byte of progress, back off from
/// `yield_now` to a short sleep so a genuinely slow peer does not cost
/// a spinning core.
const IDLE_SPINS_BEFORE_SLEEP: u32 = 256;

/// Back-off sleep once a peer has been idle past the spin budget.
const IDLE_SLEEP: Duration = Duration::from_micros(50);

/// One rank's endpoint of a TCP cluster.
pub struct TcpComm {
    rank: u16,
    size: usize,
    /// streams[r] connects to rank r (self slot `None`).
    streams: Vec<Option<TcpStream>>,
    window: u64,
    bytes_sent: u64,
    bytes_received: u64,
}

/// Receive progress of one peer's frame inside the interleaved loop.
enum RecvState {
    /// Accumulating the 4-byte length prefix.
    Header { buf: [u8; 4], pos: usize },
    /// Accumulating the payload.
    Body { buf: Vec<u8>, pos: usize },
    /// Frame complete.
    Done(Vec<u8>),
}

impl TcpComm {
    /// Join a cluster of `peers.len()` ranks as rank `rank`: bind
    /// `peers[rank]` and connect the full mesh. Blocks until every peer
    /// is connected and validated, or `timeout` expires.
    pub fn join(
        rank: u16,
        peers: &[String],
        timeout: Duration,
    ) -> Result<TcpComm> {
        ensure!(!peers.is_empty(), "peer list is empty");
        ensure!(
            (rank as usize) < peers.len(),
            "rank {rank} does not index the {}-entry peer list",
            peers.len()
        );
        let addr = &peers[rank as usize];
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("rank {rank} binding {addr}"))?;
        Self::join_with_listener(rank, listener, peers, timeout)
    }

    /// [`Self::join`] over a listener the caller already bound — lets
    /// tests and launchers use ephemeral (`:0`) ports: bind first,
    /// collect the real addresses into `peers`, then join.
    pub fn join_with_listener(
        rank: u16,
        listener: TcpListener,
        peers: &[String],
        timeout: Duration,
    ) -> Result<TcpComm> {
        let size = peers.len();
        ensure!(size >= 1, "peer list is empty");
        ensure!(
            size <= u16::MAX as usize,
            "cluster size {size} exceeds 65535 ranks"
        );
        ensure!(
            (rank as usize) < size,
            "rank {rank} does not index the {size}-entry peer list"
        );
        let deadline = Instant::now() + timeout;
        let mut streams: Vec<Option<TcpStream>> =
            (0..size).map(|_| None).collect();

        // dial every lower rank (it was launched no later than us and
        // is — or will be — listening); retry until the deadline
        for dst in 0..rank as usize {
            let stream = connect_retry(&peers[dst], deadline)
                .with_context(|| {
                    format!("rank {rank} dialing rank {dst}")
                })?;
            prepare(&stream, deadline)?;
            write_hello(&stream, rank, size)?;
            let peer = read_hello(&stream, size).with_context(|| {
                format!("rank {rank} handshaking with rank {dst}")
            })?;
            ensure!(
                peer as usize == dst,
                "address {} answered as rank {peer}, expected rank {dst} \
                 — peer list mismatch",
                peers[dst]
            );
            stream.set_read_timeout(None)?;
            streams[dst] = Some(stream);
        }

        // accept one connection from every higher rank
        listener.set_nonblocking(true)?;
        let mut missing = size - 1 - rank as usize;
        while missing > 0 {
            match listener.accept() {
                Ok((stream, addr)) => {
                    // a failed hello here (port scanner, health check,
                    // stray process, line noise) drops the connection
                    // and keeps accepting — only a *validated* cortex
                    // peer can hard-fail the join. The hello read is
                    // capped at 2 s so a silent stray cannot stall the
                    // queue behind it for the whole join timeout.
                    let hello = (|| -> Result<u16> {
                        stream.set_nonblocking(false)?;
                        stream.set_nodelay(true)?;
                        let left = deadline
                            .checked_duration_since(Instant::now())
                            .filter(|d| !d.is_zero())
                            .unwrap_or(Duration::from_millis(1));
                        stream.set_read_timeout(Some(
                            left.min(Duration::from_secs(2)),
                        ))?;
                        read_hello(&stream, size)
                    })();
                    let peer = match hello {
                        Ok(p) => p,
                        Err(e) => {
                            eprintln!(
                                "rank {rank}: rejecting a stray \
                                 connection from {addr}: {e:#}"
                            );
                            continue;
                        }
                    };
                    ensure!(
                        (peer as usize) > (rank as usize)
                            && (peer as usize) < size,
                        "unexpected connection from rank {peer}"
                    );
                    ensure!(
                        streams[peer as usize].is_none(),
                        "duplicate connection from rank {peer}"
                    );
                    write_hello(&stream, rank, size)?;
                    stream.set_read_timeout(None)?;
                    streams[peer as usize] = Some(stream);
                    missing -= 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    ensure!(
                        Instant::now() < deadline,
                        "rank {rank} timed out waiting for {missing} \
                         peer connection(s)"
                    );
                    std::thread::sleep(RETRY_EVERY);
                }
                Err(e) => {
                    return Err(anyhow!(
                        "rank {rank} accepting a peer: {e}"
                    ))
                }
            }
        }
        Ok(TcpComm {
            rank,
            size,
            streams,
            window: 0,
            bytes_sent: 0,
            bytes_received: 0,
        })
    }

    /// The interleaved collective under both the window exchange and
    /// the subscription alltoall: send `frames[p]` to every peer `p`
    /// (self slot ignored) while reading exactly one length-prefixed
    /// frame back from each, returning the received payloads indexed
    /// by source rank (self slot empty).
    ///
    /// Every stream runs nonblocking; each pass round-robins partial
    /// writes and reads over all peers, so progress on one peer never
    /// waits on another and frames larger than the socket buffers
    /// cannot deadlock the mutually-writing mesh. `window` only labels
    /// peer-loss errors.
    fn exchange_frames(
        &mut self,
        frames: Vec<Vec<u8>>,
        window: u64,
    ) -> Result<Vec<Vec<u8>>, CommError> {
        assert_eq!(frames.len(), self.size, "one frame per rank");
        for s in self.streams.iter().flatten() {
            s.set_nonblocking(true)?;
        }
        let result = self.exchange_frames_nonblocking(frames, window);
        // restore blocking mode even on failure: teardown paths may
        // still flush, and a poisoned endpoint should fail loudly on
        // I/O rather than spin on WouldBlock
        for s in self.streams.iter().flatten() {
            let _ = s.set_nonblocking(false);
        }
        result
    }

    fn exchange_frames_nonblocking(
        &mut self,
        frames: Vec<Vec<u8>>,
        window: u64,
    ) -> Result<Vec<Vec<u8>>, CommError> {
        // per-peer send buffer (length prefix + payload) and cursor
        let mut send: Vec<Option<(Vec<u8>, usize)>> =
            vec![None; self.size];
        let mut recv: Vec<Option<RecvState>> =
            (0..self.size).map(|_| None).collect();
        for (p, frame) in frames.into_iter().enumerate() {
            if self.streams[p].is_none() {
                continue;
            }
            let mut buf =
                Vec::with_capacity(4 + frame.len());
            buf.extend_from_slice(
                &(frame.len() as u32).to_le_bytes(),
            );
            buf.extend_from_slice(&frame);
            send[p] = Some((buf, 0));
            recv[p] =
                Some(RecvState::Header { buf: [0; 4], pos: 0 });
        }
        let mut idle_spins = 0u32;
        loop {
            let mut progressed = false;
            let mut pending = false;
            for p in 0..self.size {
                let Some(stream) = self.streams[p].as_mut() else {
                    continue;
                };
                // push this peer's remaining send bytes
                if let Some((buf, pos)) = send[p].as_mut() {
                    match stream.write(&buf[*pos..]) {
                        Ok(0) => {
                            return Err(CommError::Io(
                                std::io::Error::from(
                                    ErrorKind::WriteZero,
                                ),
                            ))
                        }
                        Ok(n) => {
                            *pos += n;
                            progressed = true;
                            if *pos == buf.len() {
                                send[p] = None;
                            }
                        }
                        Err(e)
                            if e.kind() == ErrorKind::WouldBlock
                                || e.kind()
                                    == ErrorKind::Interrupted => {}
                        Err(e) => return Err(CommError::Io(e)),
                    }
                    if send[p].is_some() {
                        pending = true;
                    }
                }
                // pull this peer's frame: header, then body, each
                // stage reading as much as the socket will give
                'recv: loop {
                    match recv[p].as_mut() {
                        None | Some(RecvState::Done(_)) => {
                            break 'recv
                        }
                        Some(RecvState::Header { buf, pos }) => {
                            while *pos < buf.len() {
                                match stream.read(&mut buf[*pos..]) {
                                    Ok(0) => {
                                        return Err(
                                            CommError::PeerLost {
                                                peer: p as u16,
                                                window,
                                            },
                                        )
                                    }
                                    Ok(n) => {
                                        *pos += n;
                                        progressed = true;
                                    }
                                    Err(e)
                                        if e.kind()
                                            == ErrorKind::WouldBlock
                                            || e.kind()
                                                == ErrorKind::Interrupted =>
                                    {
                                        break 'recv
                                    }
                                    Err(e) => {
                                        return Err(CommError::Io(e))
                                    }
                                }
                            }
                            let len =
                                u32::from_le_bytes(*buf) as usize;
                            if len > MAX_FRAME_BYTES {
                                return Err(
                                    CommError::FrameTooLarge {
                                        bytes: len,
                                        limit: MAX_FRAME_BYTES,
                                    },
                                );
                            }
                            recv[p] = Some(RecvState::Body {
                                buf: vec![0u8; len],
                                pos: 0,
                            });
                        }
                        Some(RecvState::Body { buf, pos }) => {
                            while *pos < buf.len() {
                                match stream.read(&mut buf[*pos..]) {
                                    Ok(0) => {
                                        return Err(
                                            CommError::PeerLost {
                                                peer: p as u16,
                                                window,
                                            },
                                        )
                                    }
                                    Ok(n) => {
                                        *pos += n;
                                        progressed = true;
                                    }
                                    Err(e)
                                        if e.kind()
                                            == ErrorKind::WouldBlock
                                            || e.kind()
                                                == ErrorKind::Interrupted =>
                                    {
                                        break 'recv
                                    }
                                    Err(e) => {
                                        return Err(CommError::Io(e))
                                    }
                                }
                            }
                            let done = std::mem::take(buf);
                            recv[p] = Some(RecvState::Done(done));
                            break 'recv;
                        }
                    }
                }
                if !matches!(
                    recv[p],
                    None | Some(RecvState::Done(_))
                ) {
                    pending = true;
                }
            }
            if !pending {
                break;
            }
            if progressed {
                idle_spins = 0;
            } else {
                idle_spins += 1;
                if idle_spins < IDLE_SPINS_BEFORE_SLEEP {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(IDLE_SLEEP);
                }
            }
        }
        Ok(recv
            .into_iter()
            .map(|r| match r {
                Some(RecvState::Done(buf)) => buf,
                None => Vec::new(),
                _ => unreachable!("loop exited with pending recv"),
            })
            .collect())
    }
}

/// Dial `addr`, retrying while the peer's listener is not up yet.
fn connect_retry(addr: &str, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!("connecting to {addr}: {e}");
                }
                std::thread::sleep(RETRY_EVERY);
            }
        }
    }
}

/// Per-stream setup: no Nagle batching (one latency-critical frame per
/// window), bounded reads during the handshake.
fn prepare(stream: &TcpStream, deadline: Instant) -> Result<()> {
    stream.set_nodelay(true)?;
    let left = deadline
        .checked_duration_since(Instant::now())
        .filter(|d| !d.is_zero())
        .unwrap_or(Duration::from_millis(1));
    stream.set_read_timeout(Some(left))?;
    Ok(())
}

fn write_hello(
    mut stream: &TcpStream,
    rank: u16,
    size: usize,
) -> Result<()> {
    let mut hello = [0u8; 14];
    hello[0..8].copy_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    hello[8..10].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    hello[10..12].copy_from_slice(&rank.to_le_bytes());
    hello[12..14].copy_from_slice(&(size as u16).to_le_bytes());
    stream.write_all(&hello)?;
    Ok(())
}

/// Read and validate a peer's hello; returns its rank.
fn read_hello(mut stream: &TcpStream, size: usize) -> Result<u16> {
    let mut hello = [0u8; 14];
    stream.read_exact(&mut hello)?;
    let magic = u64::from_le_bytes(hello[0..8].try_into().unwrap());
    ensure!(
        magic == HANDSHAKE_MAGIC,
        "bad handshake magic {magic:#018x} — not a cortex rank"
    );
    let version =
        u16::from_le_bytes(hello[8..10].try_into().unwrap());
    ensure!(
        version == WIRE_VERSION,
        "wire version mismatch: peer speaks v{version}, \
         this build speaks v{WIRE_VERSION}"
    );
    let rank = u16::from_le_bytes(hello[10..12].try_into().unwrap());
    let peer_size =
        u16::from_le_bytes(hello[12..14].try_into().unwrap()) as usize;
    ensure!(
        peer_size == size,
        "cluster size mismatch: peer expects {peer_size} ranks, \
         this rank expects {size}"
    );
    Ok(rank)
}

impl Communicator for TcpComm {
    fn rank(&self) -> u16 {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn exchange_outbound(
        &mut self,
        out: Outbound,
    ) -> Result<SpikePacket, CommError> {
        let window = self.window;
        self.window += 1;
        // encode one frame per peer (broadcast reuses the same bytes)
        let mut frames: Vec<Vec<u8>> = vec![Vec::new(); self.size];
        match &out {
            Outbound::Broadcast(local) => {
                let frame = bsb::encode_frame(window, local)?;
                for p in 0..self.size {
                    if self.streams[p].is_some() {
                        frames[p] = frame.clone();
                    }
                }
            }
            Outbound::Routed(per) => {
                assert_eq!(per.len(), self.size, "one packet per rank");
                for p in 0..self.size {
                    if self.streams[p].is_some() {
                        frames[p] =
                            bsb::encode_frame(window, &per[p])?;
                    }
                }
            }
        }
        for (p, f) in frames.iter().enumerate() {
            if f.len() > MAX_FRAME_BYTES {
                return Err(CommError::FrameTooLarge {
                    bytes: f.len(),
                    limit: MAX_FRAME_BYTES,
                });
            }
            if self.streams[p].is_some() {
                self.bytes_sent += (4 + f.len()) as u64;
            }
        }
        let payloads = self.exchange_frames(frames, window)?;
        // decode in rank order — the concatenation order LocalComm's
        // channel gather produces, so rasters stay transport-invariant
        let mut all = Vec::new();
        for (src, buf) in payloads.into_iter().enumerate() {
            if self.streams[src].is_none() {
                continue;
            }
            self.bytes_received += (4 + buf.len()) as u64;
            let (got_window, spikes) = bsb::decode_frame(&buf)?;
            if got_window != window {
                return Err(CommError::WindowMismatch {
                    got: got_window,
                    want: window,
                });
            }
            all.extend(spikes);
        }
        Ok(all)
    }

    fn alltoall(
        &mut self,
        out: Vec<Vec<u8>>,
    ) -> Result<Vec<Vec<u8>>, CommError> {
        assert_eq!(out.len(), self.size, "one blob per rank");
        for blob in &out {
            if blob.len() > MAX_FRAME_BYTES {
                return Err(CommError::FrameTooLarge {
                    bytes: blob.len(),
                    limit: MAX_FRAME_BYTES,
                });
            }
        }
        // build-time traffic: deliberately not counted in the
        // per-window bytes_sent/bytes_received volumes
        self.exchange_frames(out, self.window)
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn bytes_received(&self) -> u64 {
        self.bytes_received
    }

    fn exchanges(&self) -> u64 {
        self.window
    }

    fn send_frame(
        &mut self,
        peer: usize,
        payload: &[u8],
    ) -> Result<(), CommError> {
        if payload.len() > MAX_FRAME_BYTES {
            return Err(CommError::FrameTooLarge {
                bytes: payload.len(),
                limit: MAX_FRAME_BYTES,
            });
        }
        let window = self.window;
        let stream = self
            .streams
            .get_mut(peer)
            .and_then(|s| s.as_mut())
            .ok_or(CommError::Protocol(
                "point-to-point frame addressed to a non-peer",
            ))?;
        // relay frames travel between exchanges, when the streams are
        // in their blocking state — same length-prefixed layout as the
        // window loop
        let res = stream
            .write_all(&(payload.len() as u32).to_le_bytes())
            .and_then(|()| stream.write_all(payload));
        if let Err(e) = res {
            return Err(match e.kind() {
                ErrorKind::BrokenPipe
                | ErrorKind::ConnectionReset
                | ErrorKind::UnexpectedEof => CommError::PeerLost {
                    peer: peer as u16,
                    window,
                },
                _ => CommError::Io(e),
            });
        }
        self.bytes_sent += (4 + payload.len()) as u64;
        Ok(())
    }

    fn recv_frame(&mut self, peer: usize) -> Result<Vec<u8>, CommError> {
        let window = self.window;
        let stream = self
            .streams
            .get_mut(peer)
            .and_then(|s| s.as_mut())
            .ok_or(CommError::Protocol(
                "point-to-point frame expected from a non-peer",
            ))?;
        let lost = |e: &std::io::Error| {
            e.kind() == ErrorKind::UnexpectedEof
                || e.kind() == ErrorKind::ConnectionReset
                || e.kind() == ErrorKind::BrokenPipe
        };
        let mut header = [0u8; 4];
        match stream.read_exact(&mut header) {
            Ok(()) => {}
            Err(e) if lost(&e) => {
                return Err(CommError::PeerLost {
                    peer: peer as u16,
                    window,
                })
            }
            Err(e) => return Err(CommError::Io(e)),
        }
        let len = u32::from_le_bytes(header) as usize;
        if len > MAX_FRAME_BYTES {
            return Err(CommError::FrameTooLarge {
                bytes: len,
                limit: MAX_FRAME_BYTES,
            });
        }
        let mut payload = vec![0u8; len];
        match stream.read_exact(&mut payload) {
            Ok(()) => {}
            Err(e) if lost(&e) => {
                return Err(CommError::PeerLost {
                    peer: peer as u16,
                    window,
                })
            }
            Err(e) => return Err(CommError::Io(e)),
        }
        self.bytes_received += (4 + len) as u64;
        Ok(payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SpikeMsg;
    use std::thread;

    /// Bind ephemeral listeners, join all ranks concurrently.
    fn cluster(n: usize) -> Vec<TcpComm> {
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let peers: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let handles: Vec<_> = listeners
            .into_iter()
            .enumerate()
            .map(|(r, l)| {
                let peers = peers.clone();
                thread::spawn(move || {
                    TcpComm::join_with_listener(
                        r as u16,
                        l,
                        &peers,
                        Duration::from_secs(10),
                    )
                    .unwrap()
                })
            })
            .collect();
        let mut comms: Vec<TcpComm> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        comms.sort_by_key(|c| c.rank());
        comms
    }

    #[test]
    fn allgather_three_ranks_over_sockets() {
        let comms = cluster(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mut got = Vec::new();
                    for w in 0..5u32 {
                        let mine = vec![SpikeMsg {
                            gid: c.rank() as u32 * 10,
                            step: w,
                        }];
                        got.push(c.exchange(mine).unwrap());
                    }
                    assert_eq!(c.exchanges(), 5);
                    assert!(c.bytes_sent() > 0);
                    (c.rank(), got)
                })
            })
            .collect();
        for h in handles {
            let (rank, windows) = h.join().unwrap();
            for (w, got) in windows.into_iter().enumerate() {
                assert_eq!(got.len(), 2, "rank {rank} window {w}");
                for m in &got {
                    assert_ne!(m.gid, rank as u32 * 10);
                    assert_eq!(m.step, w as u32);
                }
            }
        }
    }

    #[test]
    fn window_mismatch_is_an_error_on_both_sides() {
        let mut comms = cluster(2);
        let mut b = comms.pop().unwrap();
        let mut a = comms.pop().unwrap();
        a.window = 3; // desynchronize rank 0
        let ha = thread::spawn(move || a.exchange(Vec::new()));
        let hb = thread::spawn(move || b.exchange(Vec::new()));
        let ea = ha.join().unwrap().unwrap_err();
        let eb = hb.join().unwrap().unwrap_err();
        assert!(
            matches!(ea, CommError::WindowMismatch { got: 0, want: 3 }),
            "rank 0: {ea}"
        );
        assert!(
            matches!(eb, CommError::WindowMismatch { got: 3, want: 0 }),
            "rank 1: {eb}"
        );
    }

    #[test]
    fn garbage_frame_is_a_codec_error_not_a_panic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dial = thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (srv, _) = listener.accept().unwrap();
        let mut peer = dial.join().unwrap();
        // a hand-built endpoint wired straight to the fake peer
        let mut comm = TcpComm {
            rank: 0,
            size: 2,
            streams: vec![None, Some(srv)],
            window: 0,
            bytes_sent: 0,
            bytes_received: 0,
        };
        // 16 bytes of 0xff: the embedded window varint overflows
        let garbage = [0xffu8; 16];
        peer.write_all(&(garbage.len() as u32).to_le_bytes()).unwrap();
        peer.write_all(&garbage).unwrap();
        let err = comm.exchange(Vec::new()).unwrap_err();
        assert!(matches!(err, CommError::Codec(_)), "{err}");
    }

    #[test]
    fn truncated_stream_is_peer_lost_not_a_panic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dial = thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (srv, _) = listener.accept().unwrap();
        let mut peer = dial.join().unwrap();
        let mut comm = TcpComm {
            rank: 0,
            size: 2,
            streams: vec![None, Some(srv)],
            window: 0,
            bytes_sent: 0,
            bytes_received: 0,
        };
        // announce 100 bytes, deliver 3, hang up mid-frame
        peer.write_all(&100u32.to_le_bytes()).unwrap();
        peer.write_all(&[1, 2, 3]).unwrap();
        drop(peer);
        let err = comm.exchange(Vec::new()).unwrap_err();
        assert!(
            matches!(err, CommError::PeerLost { peer: 1, window: 0 }),
            "{err}"
        );
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dial = thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (srv, _) = listener.accept().unwrap();
        let mut peer = dial.join().unwrap();
        let mut comm = TcpComm {
            rank: 0,
            size: 2,
            streams: vec![None, Some(srv)],
            window: 0,
            bytes_sent: 0,
            bytes_received: 0,
        };
        peer.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let err = comm.exchange(Vec::new()).unwrap_err();
        assert!(matches!(err, CommError::FrameTooLarge { .. }), "{err}");
    }

    #[test]
    fn stray_connection_is_rejected_join_times_out_without_peer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let peers = vec![
            listener.local_addr().unwrap().to_string(),
            "127.0.0.1:1".to_string(), // never dialed by rank 0
        ];
        let addr = listener.local_addr().unwrap();
        let fake = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&[0u8; 14]).unwrap(); // zero magic
            s
        });
        // the stray is dropped (not fatal); with no real rank 1 the
        // join then runs out its deadline
        let err = TcpComm::join_with_listener(
            0,
            listener,
            &peers,
            Duration::from_secs(2),
        )
        .unwrap_err();
        let _ = fake.join().unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("timed out"), "unexpected error: {msg}");
    }

    #[test]
    fn routed_exchange_over_sockets() {
        let comms = cluster(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let r = c.rank() as u32;
                    for w in 0..4u32 {
                        let per: Vec<SpikePacket> = (0..3)
                            .map(|dst| {
                                vec![SpikeMsg {
                                    gid: 100 * r + dst,
                                    step: w,
                                }]
                            })
                            .collect();
                        let got = c
                            .exchange_outbound(Outbound::Routed(per))
                            .unwrap();
                        let want: Vec<SpikeMsg> = (0..3)
                            .filter(|&src| src != r)
                            .map(|src| SpikeMsg {
                                gid: 100 * src + r,
                                step: w,
                            })
                            .collect();
                        assert_eq!(got, want, "rank {r} window {w}");
                    }
                    assert!(c.bytes_received() > 0);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn alltoall_ships_blobs_over_sockets() {
        let comms = cluster(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let r = c.rank();
                    let out: Vec<Vec<u8>> = (0..3)
                        .map(|d| vec![r as u8, d as u8, 0xCC])
                        .collect();
                    let got = c.alltoall(out).unwrap();
                    for src in 0..3u16 {
                        if src == r {
                            assert!(got[src as usize].is_empty());
                        } else {
                            assert_eq!(
                                got[src as usize],
                                vec![src as u8, r as u8, 0xCC]
                            );
                        }
                    }
                    // the collective is invisible to the window
                    // counter and the spike byte accounting
                    assert_eq!(c.exchanges(), 0);
                    assert_eq!(c.bytes_sent(), 0);
                    assert_eq!(c.bytes_received(), 0);
                    let spikes = c.exchange(Vec::new()).unwrap();
                    assert!(spikes.is_empty());
                    assert_eq!(c.exchanges(), 1);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn large_frames_complete_without_deadlock() {
        // frames far beyond the kernel socket buffers in both
        // directions at once: the interleaved nonblocking loop must
        // keep draining reads while its own writes stall. (The old
        // write-all-then-read-all exchange needed a helper thread for
        // this; the rewrite handles it in-line.)
        let comms = cluster(2);
        let n = 400_000u32;
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let r = c.rank() as u32;
                    // wide gid jumps defeat the delta coding, so the
                    // frame stays in the multi-megabyte range
                    let mine: Vec<SpikeMsg> = (0..n)
                        .map(|i| SpikeMsg {
                            gid: i.wrapping_mul(2_654_435_761) | r,
                            step: 3,
                        })
                        .collect();
                    let got = c.exchange(mine).unwrap();
                    assert_eq!(got.len(), n as usize);
                    assert!(
                        c.bytes_sent() > (1 << 20),
                        "sent frame unexpectedly small: {} bytes",
                        c.bytes_sent()
                    );
                    assert!(
                        c.bytes_received() > (1 << 20),
                        "received frame unexpectedly small: {} bytes",
                        c.bytes_received()
                    );
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn tcp_exchange_matches_local_exchange() {
        use crate::comm::LocalCluster;
        // identical per-rank spike schedules through both transports
        let spikes_of = |rank: u16, w: u32| -> Vec<SpikeMsg> {
            (0..(rank as u32 + w) % 4)
                .map(|i| SpikeMsg {
                    gid: rank as u32 * 1000 + i,
                    step: w * 10 + i,
                })
                .collect()
        };
        let windows = 6u32;
        let run = |mut comms: Vec<Box<dyn Communicator>>| -> Vec<Vec<SpikeMsg>> {
            let handles: Vec<_> = comms
                .drain(..)
                .map(|mut c| {
                    thread::spawn(move || {
                        let mut per_rank = Vec::new();
                        for w in 0..windows {
                            let mut got = c
                                .exchange(spikes_of(c.rank(), w))
                                .unwrap();
                            got.sort_unstable_by_key(|m| (m.step, m.gid));
                            per_rank.push(got);
                        }
                        (c.rank(), per_rank)
                    })
                })
                .collect();
            let mut outs: Vec<(u16, Vec<Vec<SpikeMsg>>)> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            outs.sort_by_key(|(r, _)| *r);
            outs.into_iter().flat_map(|(_, v)| v).collect()
        };
        let local: Vec<Box<dyn Communicator>> = LocalCluster::new(3)
            .into_iter()
            .map(|c| Box::new(c) as Box<dyn Communicator>)
            .collect();
        let tcp: Vec<Box<dyn Communicator>> = cluster(3)
            .into_iter()
            .map(|c| Box::new(c) as Box<dyn Communicator>)
            .collect();
        assert_eq!(run(local), run(tcp));
    }
}
