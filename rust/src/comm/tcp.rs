//! TCP rank runtime: ranks are OS **processes**, the transport is a full
//! mesh of TCP streams, and the [`bsb`] packed format is the actual
//! on-the-wire protocol — the paper's Spikes Broadcast collective
//! carried over real sockets instead of in-memory channels.
//!
//! # Cluster formation
//!
//! Every rank knows the full rank-ordered address list (`peers[r]` is
//! rank r's listen address). Rank `i` binds `peers[i]`, dials every
//! lower rank (retrying until that peer's listener is up, bounded by a
//! deadline) and accepts one connection from every higher rank. Each
//! stream opens with a fixed 14-byte handshake — magic, wire version,
//! sender rank, cluster size — validated on both ends, so a stray or
//! mis-configured process is rejected before any simulation traffic.
//!
//! # Exchange protocol
//!
//! One `exchange` call sends one **length-prefixed frame** (4-byte LE
//! length, then a [`bsb::encode_frame`] payload: varint window counter,
//! varint window start, packed spikes) to every peer and blocks reading
//! exactly one frame back from each, concatenating payloads in rank
//! order — the same send-to-all / receive-from-all collective
//! [`super::local::LocalComm`] performs, with the same deterministic
//! concatenation order, so rasters are bit-identical across the two
//! transports. The embedded window counter is verified on **every**
//! receive; a stale frame, a truncated or bit-flipped payload, or an
//! oversized length prefix each surface as a [`CommError`] — never a
//! panic — and the endpoint is considered poisoned afterwards.
//!
//! Streams run with `TCP_NODELAY` (one small latency-critical frame per
//! window per peer, the paper's §III.C traffic shape). Frames are
//! written to every peer before any is read; per-window spike payloads
//! are orders of magnitude below kernel socket buffers, so the
//! all-write-then-all-read pattern cannot deadlock at the scales the
//! in-memory engine reaches on one host.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::{bsb, CommError, Communicator, SpikePacket};

/// Handshake magic: "CORTEXTC" as LE bytes.
const HANDSHAKE_MAGIC: u64 = 0x4354_5845_5452_4f43;

/// Bump when the frame layout changes; both ends must agree.
pub const WIRE_VERSION: u16 = 1;

/// Sanity bound on one frame's payload (64 MiB ≈ tens of millions of
/// packed spikes per window per rank — far beyond anything a real
/// window produces). A length prefix above this is treated as
/// corruption, not honored with an allocation.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Poll interval while dialing a peer that is not listening yet.
const RETRY_EVERY: Duration = Duration::from_millis(50);

/// Frames up to this size are written to all peers inline before any
/// read — they fit comfortably inside default kernel socket buffers, so
/// the write side can never block on a peer that is itself still
/// writing. Larger frames (hundreds of thousands of packed spikes in
/// one window) are pushed from a helper thread instead, with this
/// thread draining reads concurrently, so a mesh of mutually-writing
/// ranks degrades to an error or completes rather than deadlocking.
const INLINE_WRITE_BYTES: usize = 1 << 18;

/// One rank's endpoint of a TCP cluster.
pub struct TcpComm {
    rank: u16,
    size: usize,
    /// streams[r] connects to rank r (self slot `None`).
    streams: Vec<Option<TcpStream>>,
    window: u64,
    bytes_sent: u64,
}

impl TcpComm {
    /// Join a cluster of `peers.len()` ranks as rank `rank`: bind
    /// `peers[rank]` and connect the full mesh. Blocks until every peer
    /// is connected and validated, or `timeout` expires.
    pub fn join(
        rank: u16,
        peers: &[String],
        timeout: Duration,
    ) -> Result<TcpComm> {
        ensure!(!peers.is_empty(), "peer list is empty");
        ensure!(
            (rank as usize) < peers.len(),
            "rank {rank} does not index the {}-entry peer list",
            peers.len()
        );
        let addr = &peers[rank as usize];
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("rank {rank} binding {addr}"))?;
        Self::join_with_listener(rank, listener, peers, timeout)
    }

    /// [`Self::join`] over a listener the caller already bound — lets
    /// tests and launchers use ephemeral (`:0`) ports: bind first,
    /// collect the real addresses into `peers`, then join.
    pub fn join_with_listener(
        rank: u16,
        listener: TcpListener,
        peers: &[String],
        timeout: Duration,
    ) -> Result<TcpComm> {
        let size = peers.len();
        ensure!(size >= 1, "peer list is empty");
        ensure!(
            size <= u16::MAX as usize,
            "cluster size {size} exceeds 65535 ranks"
        );
        ensure!(
            (rank as usize) < size,
            "rank {rank} does not index the {size}-entry peer list"
        );
        let deadline = Instant::now() + timeout;
        let mut streams: Vec<Option<TcpStream>> =
            (0..size).map(|_| None).collect();

        // dial every lower rank (it was launched no later than us and
        // is — or will be — listening); retry until the deadline
        for dst in 0..rank as usize {
            let stream = connect_retry(&peers[dst], deadline)
                .with_context(|| {
                    format!("rank {rank} dialing rank {dst}")
                })?;
            prepare(&stream, deadline)?;
            write_hello(&stream, rank, size)?;
            let peer = read_hello(&stream, size).with_context(|| {
                format!("rank {rank} handshaking with rank {dst}")
            })?;
            ensure!(
                peer as usize == dst,
                "address {} answered as rank {peer}, expected rank {dst} \
                 — peer list mismatch",
                peers[dst]
            );
            stream.set_read_timeout(None)?;
            streams[dst] = Some(stream);
        }

        // accept one connection from every higher rank
        listener.set_nonblocking(true)?;
        let mut missing = size - 1 - rank as usize;
        while missing > 0 {
            match listener.accept() {
                Ok((stream, addr)) => {
                    // a failed hello here (port scanner, health check,
                    // stray process, line noise) drops the connection
                    // and keeps accepting — only a *validated* cortex
                    // peer can hard-fail the join. The hello read is
                    // capped at 2 s so a silent stray cannot stall the
                    // queue behind it for the whole join timeout.
                    let hello = (|| -> Result<u16> {
                        stream.set_nonblocking(false)?;
                        stream.set_nodelay(true)?;
                        let left = deadline
                            .checked_duration_since(Instant::now())
                            .filter(|d| !d.is_zero())
                            .unwrap_or(Duration::from_millis(1));
                        stream.set_read_timeout(Some(
                            left.min(Duration::from_secs(2)),
                        ))?;
                        read_hello(&stream, size)
                    })();
                    let peer = match hello {
                        Ok(p) => p,
                        Err(e) => {
                            eprintln!(
                                "rank {rank}: rejecting a stray \
                                 connection from {addr}: {e:#}"
                            );
                            continue;
                        }
                    };
                    ensure!(
                        (peer as usize) > (rank as usize)
                            && (peer as usize) < size,
                        "unexpected connection from rank {peer}"
                    );
                    ensure!(
                        streams[peer as usize].is_none(),
                        "duplicate connection from rank {peer}"
                    );
                    write_hello(&stream, rank, size)?;
                    stream.set_read_timeout(None)?;
                    streams[peer as usize] = Some(stream);
                    missing -= 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    ensure!(
                        Instant::now() < deadline,
                        "rank {rank} timed out waiting for {missing} \
                         peer connection(s)"
                    );
                    std::thread::sleep(RETRY_EVERY);
                }
                Err(e) => {
                    return Err(anyhow!(
                        "rank {rank} accepting a peer: {e}"
                    ))
                }
            }
        }
        Ok(TcpComm { rank, size, streams, window: 0, bytes_sent: 0 })
    }

    /// Receive-from-all: read exactly one length-prefixed frame from
    /// every peer, verify its embedded window counter, and concatenate
    /// the payloads in rank order (the exact order
    /// [`super::local::LocalComm`]'s channel gather produces).
    fn gather(
        &mut self,
        window: u64,
    ) -> Result<SpikePacket, CommError> {
        let mut all = Vec::new();
        for src in 0..self.size {
            let Some(stream) = self.streams[src].as_mut() else {
                continue;
            };
            let mut len = [0u8; 4];
            stream.read_exact(&mut len).map_err(|e| {
                if e.kind() == ErrorKind::UnexpectedEof {
                    CommError::PeerLost { peer: src as u16, window }
                } else {
                    CommError::Io(e)
                }
            })?;
            let len = u32::from_le_bytes(len) as usize;
            if len > MAX_FRAME_BYTES {
                return Err(CommError::FrameTooLarge {
                    bytes: len,
                    limit: MAX_FRAME_BYTES,
                });
            }
            let mut buf = vec![0u8; len];
            stream.read_exact(&mut buf).map_err(|e| {
                if e.kind() == ErrorKind::UnexpectedEof {
                    CommError::PeerLost { peer: src as u16, window }
                } else {
                    CommError::Io(e)
                }
            })?;
            let (got_window, spikes) = bsb::decode_frame(&buf)?;
            if got_window != window {
                return Err(CommError::WindowMismatch {
                    got: got_window,
                    want: window,
                });
            }
            all.extend(spikes);
        }
        Ok(all)
    }
}

/// Dial `addr`, retrying while the peer's listener is not up yet.
fn connect_retry(addr: &str, deadline: Instant) -> Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!("connecting to {addr}: {e}");
                }
                std::thread::sleep(RETRY_EVERY);
            }
        }
    }
}

/// Per-stream setup: no Nagle batching (one latency-critical frame per
/// window), bounded reads during the handshake.
fn prepare(stream: &TcpStream, deadline: Instant) -> Result<()> {
    stream.set_nodelay(true)?;
    let left = deadline
        .checked_duration_since(Instant::now())
        .filter(|d| !d.is_zero())
        .unwrap_or(Duration::from_millis(1));
    stream.set_read_timeout(Some(left))?;
    Ok(())
}

fn write_hello(
    mut stream: &TcpStream,
    rank: u16,
    size: usize,
) -> Result<()> {
    let mut hello = [0u8; 14];
    hello[0..8].copy_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    hello[8..10].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    hello[10..12].copy_from_slice(&rank.to_le_bytes());
    hello[12..14].copy_from_slice(&(size as u16).to_le_bytes());
    stream.write_all(&hello)?;
    Ok(())
}

/// Read and validate a peer's hello; returns its rank.
fn read_hello(mut stream: &TcpStream, size: usize) -> Result<u16> {
    let mut hello = [0u8; 14];
    stream.read_exact(&mut hello)?;
    let magic = u64::from_le_bytes(hello[0..8].try_into().unwrap());
    ensure!(
        magic == HANDSHAKE_MAGIC,
        "bad handshake magic {magic:#018x} — not a cortex rank"
    );
    let version =
        u16::from_le_bytes(hello[8..10].try_into().unwrap());
    ensure!(
        version == WIRE_VERSION,
        "wire version mismatch: peer speaks v{version}, \
         this build speaks v{WIRE_VERSION}"
    );
    let rank = u16::from_le_bytes(hello[10..12].try_into().unwrap());
    let peer_size =
        u16::from_le_bytes(hello[12..14].try_into().unwrap()) as usize;
    ensure!(
        peer_size == size,
        "cluster size mismatch: peer expects {peer_size} ranks, \
         this rank expects {size}"
    );
    Ok(rank)
}

impl Communicator for TcpComm {
    fn rank(&self) -> u16 {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn exchange(
        &mut self,
        local: SpikePacket,
    ) -> Result<SpikePacket, CommError> {
        let window = self.window;
        self.window += 1;
        let frame = bsb::encode_frame(window, &local)?;
        if frame.len() > MAX_FRAME_BYTES {
            return Err(CommError::FrameTooLarge {
                bytes: frame.len(),
                limit: MAX_FRAME_BYTES,
            });
        }
        let len = (frame.len() as u32).to_le_bytes();
        if frame.len() <= INLINE_WRITE_BYTES {
            // the steady state: send-to-all, then receive-from-all
            for dst in 0..self.size {
                if let Some(stream) = self.streams[dst].as_mut() {
                    stream.write_all(&len)?;
                    stream.write_all(&frame)?;
                    self.bytes_sent += (4 + frame.len()) as u64;
                }
            }
            return self.gather(window);
        }
        // a frame this large could fill both directions' socket buffers
        // while every rank is still in its write loop; write on dup'd
        // handles from a helper thread so reads drain concurrently
        let mut writers: Vec<TcpStream> = Vec::new();
        for s in self.streams.iter().flatten() {
            writers.push(s.try_clone()?);
        }
        self.bytes_sent +=
            writers.len() as u64 * (4 + frame.len()) as u64;
        let frame = &frame;
        let len = &len;
        std::thread::scope(|scope| {
            let writer =
                scope.spawn(move || -> Result<(), CommError> {
                    let mut writers = writers;
                    for s in writers.iter_mut() {
                        s.write_all(len)?;
                        s.write_all(frame)?;
                    }
                    Ok(())
                });
            let got = self.gather(window);
            let wrote =
                writer.join().expect("writer thread panicked");
            wrote.and(got)
        })
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes_sent
    }

    fn exchanges(&self) -> u64 {
        self.window
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::SpikeMsg;
    use std::thread;

    /// Bind ephemeral listeners, join all ranks concurrently.
    fn cluster(n: usize) -> Vec<TcpComm> {
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let peers: Vec<String> = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        let handles: Vec<_> = listeners
            .into_iter()
            .enumerate()
            .map(|(r, l)| {
                let peers = peers.clone();
                thread::spawn(move || {
                    TcpComm::join_with_listener(
                        r as u16,
                        l,
                        &peers,
                        Duration::from_secs(10),
                    )
                    .unwrap()
                })
            })
            .collect();
        let mut comms: Vec<TcpComm> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        comms.sort_by_key(|c| c.rank());
        comms
    }

    #[test]
    fn allgather_three_ranks_over_sockets() {
        let comms = cluster(3);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mut got = Vec::new();
                    for w in 0..5u32 {
                        let mine = vec![SpikeMsg {
                            gid: c.rank() as u32 * 10,
                            step: w,
                        }];
                        got.push(c.exchange(mine).unwrap());
                    }
                    assert_eq!(c.exchanges(), 5);
                    assert!(c.bytes_sent() > 0);
                    (c.rank(), got)
                })
            })
            .collect();
        for h in handles {
            let (rank, windows) = h.join().unwrap();
            for (w, got) in windows.into_iter().enumerate() {
                assert_eq!(got.len(), 2, "rank {rank} window {w}");
                for m in &got {
                    assert_ne!(m.gid, rank as u32 * 10);
                    assert_eq!(m.step, w as u32);
                }
            }
        }
    }

    #[test]
    fn window_mismatch_is_an_error_on_both_sides() {
        let mut comms = cluster(2);
        let mut b = comms.pop().unwrap();
        let mut a = comms.pop().unwrap();
        a.window = 3; // desynchronize rank 0
        let ha = thread::spawn(move || a.exchange(Vec::new()));
        let hb = thread::spawn(move || b.exchange(Vec::new()));
        let ea = ha.join().unwrap().unwrap_err();
        let eb = hb.join().unwrap().unwrap_err();
        assert!(
            matches!(ea, CommError::WindowMismatch { got: 0, want: 3 }),
            "rank 0: {ea}"
        );
        assert!(
            matches!(eb, CommError::WindowMismatch { got: 3, want: 0 }),
            "rank 1: {eb}"
        );
    }

    #[test]
    fn garbage_frame_is_a_codec_error_not_a_panic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dial = thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (srv, _) = listener.accept().unwrap();
        let mut peer = dial.join().unwrap();
        // a hand-built endpoint wired straight to the fake peer
        let mut comm = TcpComm {
            rank: 0,
            size: 2,
            streams: vec![None, Some(srv)],
            window: 0,
            bytes_sent: 0,
        };
        // 16 bytes of 0xff: the embedded window varint overflows
        let garbage = [0xffu8; 16];
        peer.write_all(&(garbage.len() as u32).to_le_bytes()).unwrap();
        peer.write_all(&garbage).unwrap();
        let err = comm.exchange(Vec::new()).unwrap_err();
        assert!(matches!(err, CommError::Codec(_)), "{err}");
    }

    #[test]
    fn truncated_stream_is_peer_lost_not_a_panic() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dial = thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (srv, _) = listener.accept().unwrap();
        let mut peer = dial.join().unwrap();
        let mut comm = TcpComm {
            rank: 0,
            size: 2,
            streams: vec![None, Some(srv)],
            window: 0,
            bytes_sent: 0,
        };
        // announce 100 bytes, deliver 3, hang up mid-frame
        peer.write_all(&100u32.to_le_bytes()).unwrap();
        peer.write_all(&[1, 2, 3]).unwrap();
        drop(peer);
        let err = comm.exchange(Vec::new()).unwrap_err();
        assert!(
            matches!(err, CommError::PeerLost { peer: 1, window: 0 }),
            "{err}"
        );
    }

    #[test]
    fn oversized_length_prefix_is_rejected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dial = thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (srv, _) = listener.accept().unwrap();
        let mut peer = dial.join().unwrap();
        let mut comm = TcpComm {
            rank: 0,
            size: 2,
            streams: vec![None, Some(srv)],
            window: 0,
            bytes_sent: 0,
        };
        peer.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let err = comm.exchange(Vec::new()).unwrap_err();
        assert!(matches!(err, CommError::FrameTooLarge { .. }), "{err}");
    }

    #[test]
    fn stray_connection_is_rejected_join_times_out_without_peer() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let peers = vec![
            listener.local_addr().unwrap().to_string(),
            "127.0.0.1:1".to_string(), // never dialed by rank 0
        ];
        let addr = listener.local_addr().unwrap();
        let fake = thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&[0u8; 14]).unwrap(); // zero magic
            s
        });
        // the stray is dropped (not fatal); with no real rank 1 the
        // join then runs out its deadline
        let err = TcpComm::join_with_listener(
            0,
            listener,
            &peers,
            Duration::from_secs(2),
        )
        .unwrap_err();
        let _ = fake.join().unwrap();
        let msg = format!("{err:#}");
        assert!(msg.contains("timed out"), "unexpected error: {msg}");
    }

    #[test]
    fn tcp_exchange_matches_local_exchange() {
        use crate::comm::LocalCluster;
        // identical per-rank spike schedules through both transports
        let spikes_of = |rank: u16, w: u32| -> Vec<SpikeMsg> {
            (0..(rank as u32 + w) % 4)
                .map(|i| SpikeMsg {
                    gid: rank as u32 * 1000 + i,
                    step: w * 10 + i,
                })
                .collect()
        };
        let windows = 6u32;
        let run = |mut comms: Vec<Box<dyn Communicator>>| -> Vec<Vec<SpikeMsg>> {
            let handles: Vec<_> = comms
                .drain(..)
                .map(|mut c| {
                    thread::spawn(move || {
                        let mut per_rank = Vec::new();
                        for w in 0..windows {
                            let mut got = c
                                .exchange(spikes_of(c.rank(), w))
                                .unwrap();
                            got.sort_unstable_by_key(|m| (m.step, m.gid));
                            per_rank.push(got);
                        }
                        (c.rank(), per_rank)
                    })
                })
                .collect();
            let mut outs: Vec<(u16, Vec<Vec<SpikeMsg>>)> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            outs.sort_by_key(|(r, _)| *r);
            outs.into_iter().flat_map(|(_, v)| v).collect()
        };
        let local: Vec<Box<dyn Communicator>> = LocalCluster::new(3)
            .into_iter()
            .map(|c| Box::new(c) as Box<dyn Communicator>)
            .collect();
        let tcp: Vec<Box<dyn Communicator>> = cluster(3)
            .into_iter()
            .map(|c| Box::new(c) as Box<dyn Communicator>)
            .collect();
        assert_eq!(run(local), run(tcp));
    }
}
