//! Hierarchical spike exchange: merged packets over two-level routing
//! (paper §III.C, and "A Low-latency Communication Design for Brain
//! Simulations" — merged spike packets plus intra-host/inter-host
//! routing keep spike delivery sub-linear where a full mesh collapses).
//!
//! Ranks are partitioned into **host groups** ([`CommGroups`], config
//! `engine.comm_group`, auto-assigned by `cortex launch`). Each group
//! elects its lowest rank as the **relay**; one window exchange then
//! runs in three rounds instead of a flat per-peer mesh:
//!
//! ```text
//!   group 0                         group 1
//!   ┌──────────────┐               ┌──────────────┐
//!   │ r1 ─┐        │   merged      │        ┌─ r3 │
//!   │     ├─ r0 ═══╪═══════════════╪══ r2 ──┤     │
//!   │ ····┘ (relay)│  multi-source │(relay) └···· │
//!   └──────────────┘    frames     └──────────────┘
//!    A: gather        B: relay ↔ relay       C: scatter
//! ```
//!
//! * **A (gather)** — every member hands its relay one frame bundling
//!   its per-destination routed packets;
//! * **B (relay exchange)** — relays exchange one merged multi-source
//!   frame per destination *group* ([`bsb::encode_merged`]), carrying
//!   every member's sub-frame for every rank of that group — the
//!   O(groups²) wire stage that replaces the O(ranks²) mesh;
//! * **C (scatter)** — each relay re-buckets by destination rank and
//!   hands every member its sub-frames.
//!
//! The receiver sorts its sub-frames by source rank before
//! concatenating, which reproduces the flat exchange's source-rank
//! delivery order — hierarchical is **bit-identical to routed and
//! broadcast by construction**, it only changes who carries the bytes.
//!
//! Co-located members of a group (ranks hosted by the same process)
//! skip the transport entirely: the session wires them an in-process
//! channel fast path ([`FastLink`]), so intra-group rounds never touch
//! loopback TCP. Inter-group traffic stays on the wrapped transport's
//! point-to-point frames ([`Communicator::send_frame`]).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};

use super::{
    bsb, bsb::MergedEntry, CommError, Communicator, Outbound,
    SpikePacket, MAX_FRAME_BYTES,
};

/// The host-group topology: which group each rank belongs to. Group
/// ids must be contiguous from zero and every group non-empty; the
/// relay of a group is its lowest rank.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CommGroups {
    group_of: Vec<usize>,
    members: Vec<Vec<usize>>,
}

impl CommGroups {
    /// Validate a per-rank group-id assignment (`group_of[r]` is rank
    /// `r`'s group).
    pub fn new(group_of: Vec<usize>) -> Result<CommGroups, CommError> {
        if group_of.is_empty() {
            return Err(CommError::Protocol(
                "comm groups need at least one rank",
            ));
        }
        let n_groups = group_of.iter().copied().max().unwrap_or(0) + 1;
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
        for (r, &g) in group_of.iter().enumerate() {
            members[g].push(r);
        }
        if members.iter().any(|m| m.is_empty()) {
            return Err(CommError::Protocol(
                "comm group ids must be contiguous from zero",
            ));
        }
        Ok(CommGroups { group_of, members })
    }

    /// Evenly chop `ranks` into groups of (up to) `group_size`
    /// consecutive ranks — the shape `cortex launch` auto-assigns.
    pub fn even(ranks: usize, group_size: usize) -> CommGroups {
        let gs = group_size.max(1);
        CommGroups::new((0..ranks).map(|r| r / gs).collect())
            .expect("even grouping is always valid")
    }

    pub fn n_ranks(&self) -> usize {
        self.group_of.len()
    }

    pub fn n_groups(&self) -> usize {
        self.members.len()
    }

    pub fn group_of(&self, rank: usize) -> usize {
        self.group_of[rank]
    }

    /// Ranks of group `g`, ascending.
    pub fn members(&self, g: usize) -> &[usize] {
        &self.members[g]
    }

    /// The relay (lowest rank) of group `g`.
    pub fn relay(&self, g: usize) -> usize {
        self.members[g][0]
    }

    /// The relay of `rank`'s own group.
    pub fn relay_of(&self, rank: usize) -> usize {
        self.relay(self.group_of[rank])
    }

    pub fn is_relay(&self, rank: usize) -> bool {
        self.relay_of(rank) == rank
    }

    /// The per-rank group-id assignment this topology was built from.
    pub fn assignment(&self) -> &[usize] {
        &self.group_of
    }
}

/// One direction pair of an in-process fast path between two
/// co-located ranks: frames sent here never touch the transport.
pub struct FastLink {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

/// Wire in-process channel links for every **same-group** pair among
/// the ranks this process hosts (`present`). Returns each rank's
/// peer→link map, to hand to [`HierarchicalComm::with_fastpath`].
/// Inter-group pairs are left on the transport on purpose — that is
/// the traffic the relay merge exists for.
pub fn fastpath_links(
    groups: &CommGroups,
    present: &[usize],
) -> HashMap<usize, HashMap<usize, FastLink>> {
    let mut links: HashMap<usize, HashMap<usize, FastLink>> =
        present.iter().map(|&r| (r, HashMap::new())).collect();
    for (i, &a) in present.iter().enumerate() {
        for &b in &present[i + 1..] {
            if groups.group_of(a) != groups.group_of(b) {
                continue;
            }
            let (ab_tx, ab_rx) = channel::<Vec<u8>>();
            let (ba_tx, ba_rx) = channel::<Vec<u8>>();
            links
                .get_mut(&a)
                .expect("present rank")
                .insert(b, FastLink { tx: ab_tx, rx: ba_rx });
            links
                .get_mut(&b)
                .expect("present rank")
                .insert(a, FastLink { tx: ba_tx, rx: ab_rx });
        }
    }
    links
}

/// The hierarchical exchange endpoint: wraps any transport and runs
/// the gather / relay-exchange / scatter protocol over its
/// point-to-point frames (plus the in-process fast path where wired).
/// Like the flat transports, an endpoint that has returned an error is
/// poisoned and must not be reused.
pub struct HierarchicalComm {
    inner: Box<dyn Communicator>,
    groups: CommGroups,
    fastpath: HashMap<usize, FastLink>,
    /// Cap on any assembled merged frame ([`MAX_FRAME_BYTES`] unless
    /// narrowed for testing).
    frame_limit: usize,
    window: u64,
    exchanges: u64,
    frames: u64,
    fast_bytes_sent: u64,
    fast_bytes_received: u64,
}

impl HierarchicalComm {
    /// Wrap `inner`; `groups` must span exactly `inner.size()` ranks.
    pub fn new(
        inner: Box<dyn Communicator>,
        groups: CommGroups,
    ) -> Result<HierarchicalComm, CommError> {
        if groups.n_ranks() != inner.size() {
            return Err(CommError::Protocol(
                "comm group assignment does not span the cluster",
            ));
        }
        Ok(HierarchicalComm {
            inner,
            groups,
            fastpath: HashMap::new(),
            frame_limit: MAX_FRAME_BYTES,
            window: 0,
            exchanges: 0,
            frames: 0,
            fast_bytes_sent: 0,
            fast_bytes_received: 0,
        })
    }

    /// Install in-process links ([`fastpath_links`]) for co-located
    /// same-group peers.
    pub fn with_fastpath(
        mut self,
        links: HashMap<usize, FastLink>,
    ) -> HierarchicalComm {
        self.fastpath = links;
        self
    }

    /// Narrow the merged-frame cap (testing the over-merge refusal
    /// without assembling 64 MiB of spikes).
    pub fn with_frame_limit(mut self, limit: usize) -> HierarchicalComm {
        self.frame_limit = limit;
        self
    }

    pub fn groups(&self) -> &CommGroups {
        &self.groups
    }

    fn send_to(
        &mut self,
        peer: usize,
        frame: Vec<u8>,
    ) -> Result<(), CommError> {
        self.frames += 1;
        match self.fastpath.get(&peer) {
            Some(link) => {
                self.fast_bytes_sent += frame.len() as u64;
                link.tx.send(frame).map_err(|_| CommError::PeerLost {
                    peer: peer as u16,
                    window: self.window,
                })
            }
            None => self.inner.send_frame(peer, &frame),
        }
    }

    fn recv_from(&mut self, peer: usize) -> Result<Vec<u8>, CommError> {
        match self.fastpath.get(&peer) {
            Some(link) => {
                let frame =
                    link.rx.recv().map_err(|_| CommError::PeerLost {
                        peer: peer as u16,
                        window: self.window,
                    })?;
                self.fast_bytes_received += frame.len() as u64;
                Ok(frame)
            }
            None => self.inner.recv_frame(peer),
        }
    }

    /// Decode a protocol frame and verify its window counter.
    fn decode_round(
        &self,
        buf: &[u8],
    ) -> Result<Vec<MergedEntry>, CommError> {
        let (got, entries) = bsb::decode_merged(buf)?;
        if got != self.window {
            return Err(CommError::WindowMismatch {
                got,
                want: self.window,
            });
        }
        Ok(entries)
    }

    fn encode_round(
        &self,
        entries: &[MergedEntry],
    ) -> Result<Vec<u8>, CommError> {
        match bsb::encode_merged(self.window, entries, self.frame_limit)
        {
            Ok(frame) => Ok(frame),
            Err(bsb::CodecError::Oversize { bytes, limit }) => {
                Err(CommError::FrameTooLarge { bytes, limit })
            }
            Err(e) => Err(CommError::Codec(e)),
        }
    }

    /// The member side: one gather frame up to the relay, one scatter
    /// frame back down.
    fn member_exchange(
        &mut self,
        per: Vec<SpikePacket>,
    ) -> Result<Vec<MergedEntry>, CommError> {
        let rank = self.inner.rank() as usize;
        let relay = self.groups.relay_of(rank);
        let entries: Vec<MergedEntry> = per
            .into_iter()
            .enumerate()
            .filter(|(d, p)| *d != rank && !p.is_empty())
            .map(|(d, spikes)| MergedEntry {
                source: rank as u16,
                dest: d as u16,
                spikes,
            })
            .collect();
        let frame = self.encode_round(&entries)?;
        self.send_to(relay, frame)?;
        let buf = self.recv_from(relay)?;
        let inbound = self.decode_round(&buf)?;
        for e in &inbound {
            if e.dest as usize != rank {
                return Err(CommError::Protocol(
                    "scatter sub-frame addressed to another rank",
                ));
            }
        }
        Ok(inbound)
    }

    /// The relay side: gather the group's sub-frames, exchange merged
    /// multi-source frames with every other relay, scatter to members.
    fn relay_exchange(
        &mut self,
        per: Vec<SpikePacket>,
    ) -> Result<Vec<MergedEntry>, CommError> {
        let rank = self.inner.rank() as usize;
        let size = self.inner.size();
        let g = self.groups.group_of(rank);

        // own packets join the pool directly (source == relay)
        let mut pool: Vec<MergedEntry> = per
            .into_iter()
            .enumerate()
            .filter(|(d, p)| *d != rank && !p.is_empty())
            .map(|(d, spikes)| MergedEntry {
                source: rank as u16,
                dest: d as u16,
                spikes,
            })
            .collect();

        // round A: every member's bundle, in rank order
        let members: Vec<usize> = self.groups.members(g).to_vec();
        for &m in members.iter().filter(|&&m| m != rank) {
            let buf = self.recv_from(m)?;
            let entries = self.decode_round(&buf)?;
            for e in &entries {
                if e.source as usize != m || e.dest as usize >= size {
                    return Err(CommError::Protocol(
                        "gather sub-frame claims a foreign source \
                         or an out-of-range destination",
                    ));
                }
            }
            pool.extend(entries);
        }

        // round B: one merged multi-source frame per destination
        // group, pairwise-ordered against each partner relay (lower
        // rank sends first) so blocking point-to-point frames cannot
        // deadlock
        let mut partners: Vec<(usize, usize)> = (0..self
            .groups
            .n_groups())
            .filter(|&h| h != g)
            .map(|h| (h, self.groups.relay(h)))
            .collect();
        partners.sort_by_key(|&(_, relay)| relay);
        let mut delivered: Vec<MergedEntry> = Vec::new();
        for (h, partner) in partners {
            let outbound: Vec<MergedEntry> = pool
                .iter()
                .filter(|e| {
                    self.groups.group_of(e.dest as usize) == h
                })
                .cloned()
                .collect();
            let frame = self.encode_round(&outbound)?;
            let buf = if rank < partner {
                self.send_to(partner, frame)?;
                self.recv_from(partner)?
            } else {
                let buf = self.recv_from(partner)?;
                self.send_to(partner, frame)?;
                buf
            };
            let entries = self.decode_round(&buf)?;
            for e in &entries {
                let src = e.source as usize;
                let dst = e.dest as usize;
                if src >= size
                    || self.groups.group_of(src) != h
                    || dst >= size
                    || self.groups.group_of(dst) != g
                {
                    return Err(CommError::Protocol(
                        "merged sub-frame crosses the wrong group \
                         boundary",
                    ));
                }
            }
            delivered.extend(entries);
        }

        // intra-group packets never left this relay
        delivered.extend(
            pool.into_iter().filter(|e| {
                self.groups.group_of(e.dest as usize) == g
            }),
        );

        // round C: scatter per member
        for &m in members.iter().filter(|&&m| m != rank) {
            let for_m: Vec<MergedEntry> = delivered
                .iter()
                .filter(|e| e.dest as usize == m)
                .cloned()
                .collect();
            let frame = self.encode_round(&for_m)?;
            self.send_to(m, frame)?;
        }
        delivered.retain(|e| e.dest as usize == rank);
        Ok(delivered)
    }
}

impl Communicator for HierarchicalComm {
    fn rank(&self) -> u16 {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn exchange_outbound(
        &mut self,
        out: Outbound,
    ) -> Result<SpikePacket, CommError> {
        let rank = self.inner.rank() as usize;
        let size = self.inner.size();
        // normalize to per-destination packets; a broadcast submission
        // simply replicates the packet per destination (the hierarchy
        // merges it the same way)
        let per: Vec<SpikePacket> = match out {
            Outbound::Routed(per) => per,
            Outbound::Broadcast(p) => (0..size)
                .map(|d| if d == rank { Vec::new() } else { p.clone() })
                .collect(),
        };
        if per.len() != size {
            return Err(CommError::Protocol(
                "routed submission does not span the cluster",
            ));
        }
        let mut inbound = if self.groups.is_relay(rank) {
            self.relay_exchange(per)?
        } else {
            self.member_exchange(per)?
        };
        for e in &inbound {
            if e.source as usize == rank
                || e.source as usize >= size
            {
                return Err(CommError::Protocol(
                    "inbound sub-frame claims an impossible source",
                ));
            }
        }
        // source-rank order is what the flat mesh delivers; restoring
        // it here is the bit-identity argument in one line
        inbound.sort_by_key(|e| e.source);
        let got =
            inbound.into_iter().flat_map(|e| e.spikes).collect();
        self.window += 1;
        self.exchanges += 1;
        Ok(got)
    }

    fn alltoall(
        &mut self,
        out: Vec<Vec<u8>>,
    ) -> Result<Vec<Vec<u8>>, CommError> {
        self.inner.alltoall(out)
    }

    fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent() + self.fast_bytes_sent
    }

    fn bytes_received(&self) -> u64 {
        self.inner.bytes_received() + self.fast_bytes_received
    }

    fn exchanges(&self) -> u64 {
        self.exchanges
    }

    fn frames_sent(&self) -> u64 {
        self.frames
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{LocalCluster, SpikeMsg};

    #[test]
    fn groups_validate_shape() {
        assert!(CommGroups::new(vec![]).is_err());
        // group 1 empty
        assert!(CommGroups::new(vec![0, 0, 2]).is_err());
        let g = CommGroups::new(vec![0, 1, 0, 1]).unwrap();
        assert_eq!(g.n_groups(), 2);
        assert_eq!(g.members(0), &[0, 2]);
        assert_eq!(g.relay(1), 1);
        assert!(g.is_relay(0) && !g.is_relay(2));
        let even = CommGroups::even(5, 2);
        assert_eq!(even.assignment(), &[0, 0, 1, 1, 2]);
    }

    fn msg(gid: u32, step: u32) -> SpikeMsg {
        SpikeMsg { gid, step }
    }

    /// Run one routed window through the hierarchy over in-process
    /// channels and compare against the flat mesh, for several group
    /// shapes.
    #[test]
    fn hierarchical_matches_flat_mesh() {
        for (ranks, assignment) in [
            (2usize, vec![0usize, 0]),
            (2, vec![0, 1]),
            (4, vec![0, 0, 1, 1]),
            (4, vec![0, 1, 1, 0]),
            (6, vec![0, 0, 0, 1, 1, 1]),
        ] {
            let groups = CommGroups::new(assignment.clone()).unwrap();
            // per[src][dst]: a distinct packet per directed pair
            let per: Vec<Vec<SpikePacket>> = (0..ranks)
                .map(|s| {
                    (0..ranks)
                        .map(|d| {
                            if s == d {
                                Vec::new()
                            } else {
                                vec![
                                    msg((s * 100 + d) as u32, 3),
                                    msg((s * 100 + d + 50) as u32, 4),
                                ]
                            }
                        })
                        .collect()
                })
                .collect();

            let flat: Vec<SpikePacket> = LocalCluster::new(ranks)
                .into_iter()
                .zip(per.clone())
                .map(|(mut c, out)| {
                    std::thread::spawn(move || {
                        c.exchange_outbound(Outbound::Routed(out))
                            .unwrap()
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect();

            let hier: Vec<SpikePacket> = LocalCluster::new(ranks)
                .into_iter()
                .zip(per)
                .map(|(c, out)| {
                    let groups = groups.clone();
                    std::thread::spawn(move || {
                        let mut h = HierarchicalComm::new(
                            Box::new(c),
                            groups,
                        )
                        .unwrap();
                        h.exchange_outbound(Outbound::Routed(out))
                            .unwrap()
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect();

            assert_eq!(
                hier, flat,
                "{ranks} ranks, groups {assignment:?}"
            );
        }
    }

    #[test]
    fn fastpath_carries_intra_group_rounds() {
        let ranks = 4;
        let groups = CommGroups::even(ranks, 2);
        let links = fastpath_links(
            &groups,
            &(0..ranks).collect::<Vec<_>>(),
        );
        let mut links: Vec<_> = {
            let mut v: Vec<_> = links.into_iter().collect();
            v.sort_by_key(|(r, _)| *r);
            v
        };
        let handles: Vec<_> = LocalCluster::new(ranks)
            .into_iter()
            .enumerate()
            .map(|(r, c)| {
                let groups = groups.clone();
                let my = std::mem::take(&mut links[r].1);
                std::thread::spawn(move || {
                    let mut h = HierarchicalComm::new(
                        Box::new(c),
                        groups,
                    )
                    .unwrap()
                    .with_fastpath(my);
                    let out = Outbound::Broadcast(vec![msg(
                        r as u32, 7,
                    )]);
                    let got = h.exchange_outbound(out).unwrap();
                    (got, h.fast_bytes_sent, h.frames_sent())
                })
            })
            .collect();
        let results: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (r, (got, fast_sent, frames)) in
            results.iter().enumerate()
        {
            let want: SpikePacket = (0..ranks)
                .filter(|&s| s != r)
                .map(|s| msg(s as u32, 7))
                .collect();
            assert_eq!(got, &want, "rank {r}");
            // every rank talks to its group-mate over the fast path
            assert!(*fast_sent > 0, "rank {r} skipped the fast path");
            // members send 1 frame; relays 1 gather-reply + 1 inter
            assert!(*frames <= 2, "rank {r}: {frames} frames");
        }
        // frames/window across the cluster: 2 members × 1 + 2 relays
        // × 2 = 6, vs the flat mesh's 4 × 3 = 12
        let total: u64 =
            results.iter().map(|(_, _, f)| f).sum();
        assert_eq!(total, 6);
    }

    #[test]
    fn oversize_merge_is_a_typed_error() {
        // two members' packets individually under the (narrowed) cap
        // merge past it at the relay: the relay must refuse with
        // FrameTooLarge, not ship a frame the peer rejects
        let groups = CommGroups::new(vec![0, 0, 1]).unwrap();
        let pkt: SpikePacket =
            (0..64u32).map(|i| msg(i * 37 % 500, 9)).collect();
        let single =
            bsb::encode_merged(0, &[], usize::MAX).unwrap().len()
                + bsb::pack(9, &pkt).unwrap().len()
                + 8;
        let handles: Vec<_> = LocalCluster::new(3)
            .into_iter()
            .enumerate()
            .map(|(r, c)| {
                let groups = groups.clone();
                let pkt = pkt.clone();
                std::thread::spawn(move || {
                    let mut h = HierarchicalComm::new(
                        Box::new(c),
                        groups,
                    )
                    .unwrap()
                    // one sub-frame fits, the relay's two-source
                    // merge does not
                    .with_frame_limit(single + single / 2);
                    let per = (0..3)
                        .map(|d| {
                            if d == r {
                                Vec::new()
                            } else {
                                pkt.clone()
                            }
                        })
                        .collect();
                    h.exchange_outbound(Outbound::Routed(per))
                })
            })
            .collect();
        let results: Vec<_> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(
            results.iter().any(|r| matches!(
                r,
                Err(CommError::FrameTooLarge { .. })
            )),
            "no rank refused the over-cap merge"
        );
    }
}
