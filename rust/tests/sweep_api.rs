//! Ensemble/sweep acceptance bar: sharing one built network across N
//! trajectories may change ownership, never arithmetic.
//!
//! * every ensemble trajectory (drive seed + DC/Poisson overrides) is
//!   **bit-identical** — raster and checkpoint bytes — to a standalone
//!   session that builds its own store and issues the same schedule,
//!   across thread counts 1/2/4 and both exchange modes;
//! * the rank stores are genuinely shared (`Arc` refcounts rise per
//!   trajectory) and a plastic trajectory's STDP updates never leak
//!   into a sibling;
//! * distinct drive seeds decorrelate trajectories, equal seeds
//!   reproduce them;
//! * `cortex sweep` runs a `[sweep]` grid end-to-end and writes the
//!   results JSON.

use std::sync::Arc;

use cortex::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use cortex::atlas::random_spec;
use cortex::config::CommMode;
use cortex::engine::{Ensemble, RunConfig, Simulation};
use cortex::probe::{SpikeRaster, WeightSnapshots};

fn base_cfg(threads: usize, comm: CommMode) -> RunConfig {
    RunConfig {
        ranks: 2,
        threads,
        comm,
        steps: 200,
        record_limit: Some(u32::MAX),
        verify_ownership: true,
        seed: 11,
        ..Default::default()
    }
}

/// Raster + checkpoint bytes after 200 steps under a fixed stimulus
/// schedule (drive seed 99, DC on E, Poisson override on I).
fn run_schedule(mut sim: Simulation) -> (Vec<(u64, u32)>, Vec<u8>) {
    sim.run_for(200).unwrap();
    let raster =
        sim.drain("raster").unwrap().into_raster().unwrap();
    let mut blob = Vec::new();
    sim.checkpoint(&mut blob).unwrap();
    (raster, blob)
}

#[test]
fn trajectories_bit_identical_to_standalone_builds() {
    let spec = Arc::new(random_spec(400, 40, 11));
    let mut reference: Option<Vec<(u64, u32)>> = None;
    for comm in [CommMode::Serialized, CommMode::Overlap] {
        for threads in [1usize, 2, 4] {
            let cfg = base_cfg(threads, comm);
            // one shared build, then a trajectory with overrides
            let ens = Ensemble::builder(Arc::clone(&spec))
                .run_config(&cfg)
                .build()
                .unwrap();
            let traj = ens
                .trajectory()
                .drive_seed(99)
                .dc("E", 120.0)
                .poisson("I", 9_000.0, 87.8)
                .probe(SpikeRaster::all("raster"))
                .build()
                .unwrap();
            let (raster_e, blob_e) = run_schedule(traj);
            assert!(!raster_e.is_empty(), "network should be active");

            // standalone: own build, same schedule in the same order
            let mut solo = Simulation::builder(Arc::clone(&spec))
                .run_config(&cfg)
                .drive_seed(99)
                .probe(SpikeRaster::all("raster"))
                .build()
                .unwrap();
            solo.set_dc("E", 120.0).unwrap();
            solo.set_poisson("I", 9_000.0, 87.8).unwrap();
            let (raster_s, blob_s) = run_schedule(solo);

            assert_eq!(
                raster_e, raster_s,
                "{comm:?}/{threads}t: shared-store trajectory raster \
                 diverged from its standalone build"
            );
            assert_eq!(
                blob_e, blob_s,
                "{comm:?}/{threads}t: checkpoint bytes diverged"
            );
            // and the result is thread/comm invariant like any run
            if let Some(want) = &reference {
                assert_eq!(
                    want, &raster_e,
                    "{comm:?}/{threads}t changed the raster"
                );
            } else {
                reference = Some(raster_e);
            }
        }
    }
}

#[test]
fn stores_are_shared_and_memory_split_is_consistent() {
    let spec = Arc::new(random_spec(400, 40, 7));
    let cfg = RunConfig {
        ranks: 2,
        threads: 2,
        seed: 7,
        ..Default::default()
    };
    let ens = Ensemble::builder(Arc::clone(&spec))
        .run_config(&cfg)
        .build()
        .unwrap();
    let before = Arc::strong_count(ens.network().store(0));
    let mut a = ens.trajectory().build().unwrap();
    let mut b = ens.trajectory().drive_seed(1).build().unwrap();
    assert!(
        Arc::strong_count(ens.network().store(0)) >= before + 2,
        "each trajectory should hold the shared store, not a copy"
    );
    // the split accounting covers the merged report exactly
    let (shared, state) = a.memory_split().unwrap();
    assert!(shared > 0 && state > 0);
    assert_eq!(shared + state, a.memory().unwrap().total_bytes());
    assert_eq!(
        shared,
        ens.shared_memory().total_bytes(),
        "trajectory shared bytes must equal the ensemble's own report"
    );
    a.run_for(20).unwrap();
    b.run_for(20).unwrap();
    a.finish().unwrap();
    b.finish().unwrap();
}

#[test]
fn drive_seeds_decorrelate_and_reproduce() {
    let spec = Arc::new(random_spec(400, 40, 19));
    let ens = Ensemble::builder(Arc::clone(&spec))
        .ranks(1)
        .threads(2)
        .record_limit(Some(u32::MAX))
        .build()
        .unwrap();
    let run = |seed: u64| {
        let mut sim = ens
            .trajectory()
            .drive_seed(seed)
            .probe(SpikeRaster::all("raster"))
            .build()
            .unwrap();
        sim.run_for(200).unwrap();
        sim.drain("raster").unwrap().into_raster().unwrap()
    };
    let (a, b, a2) = (run(1), run(2), run(1));
    assert!(!a.is_empty(), "network should be active");
    assert_eq!(a, a2, "equal drive seeds must reproduce the raster");
    assert_ne!(a, b, "distinct drive seeds should decorrelate noise");
}

#[test]
fn plastic_trajectories_do_not_leak_weights_into_siblings() {
    let spec = Arc::new(hpc_benchmark_spec(
        &HpcParams {
            n_neurons: 500,
            indegree: 100,
            plastic: true,
            eta: 0.95,
            ..Default::default()
        },
        29,
    ));
    let cfg = RunConfig {
        ranks: 1,
        threads: 2,
        verify_ownership: true,
        seed: 29,
        ..Default::default()
    };
    let weights_of = |mut sim: Simulation| {
        sim.run_for(120).unwrap();
        let w = sim.drain("w").unwrap().into_weights().unwrap();
        w.into_iter().last().unwrap().1
    };
    // standalone reference
    let solo = Simulation::builder(Arc::clone(&spec))
        .run_config(&cfg)
        .probe(WeightSnapshots::new("w"))
        .build()
        .unwrap();
    let w_solo = weights_of(solo);
    assert!(!w_solo.is_empty(), "network should have plastic edges");

    // run a *hotter* sibling first — if trajectories shared mutable
    // weights, its STDP updates would contaminate the plain one
    let ens = Ensemble::builder(Arc::clone(&spec))
        .run_config(&cfg)
        .build()
        .unwrap();
    let hot = ens
        .trajectory()
        .drive_seed(777)
        .poisson("E", 20_000.0, 87.8)
        .probe(WeightSnapshots::new("w"))
        .build()
        .unwrap();
    let w_hot = weights_of(hot);
    let plain = ens
        .trajectory()
        .probe(WeightSnapshots::new("w"))
        .build()
        .unwrap();
    let w_plain = weights_of(plain);
    assert_ne!(
        w_hot, w_plain,
        "the stimulus override should actually move weights"
    );
    assert_eq!(
        w_solo, w_plain,
        "sibling trajectory's plasticity leaked into the shared store"
    );
}

#[test]
fn sweep_cli_runs_a_grid_and_writes_json() {
    let dir = std::env::temp_dir()
        .join(format!("cortex-sweep-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let config = dir.join("sweep.toml");
    std::fs::write(
        &config,
        r#"
title = "sweep smoke"
[network]
kind = "random"
n_neurons = 300
indegree = 30
[sim]
sim_ms = 10
[engine]
ranks = 1
threads = 2
[sweep]
steps = 60
parallel = 2
seeds = [1, 2]
dc = ["E:50"]
"#,
    )
    .unwrap();
    let out = dir.join("sweep.json");
    let argv: Vec<String> = [
        "sweep",
        "--config",
        config.to_str().unwrap(),
        "--out",
        out.to_str().unwrap(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    cortex::cli::main_with(&argv).unwrap();
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(
        text.contains("\"trajectories\""),
        "results JSON should list trajectories: {text}"
    );
    assert!(text.contains("\"shared_build_seconds\""));
    let _ = std::fs::remove_dir_all(&dir);
}
