//! The tentpole's safety net: the branch-free "vector" neuron kernels
//! must be **bit-identical** to the scalar originals — not approximately,
//! not statistically. Property tests (via `util::proptest_lite`, replay
//! with `CORTEX_PROPTEST_SEED`) drive both formulations over random
//! parameter sets, mixed-`pidx` blocks whose sizes straddle the 64-lane
//! mask chunks, and bombardment inputs strong enough to exercise the
//! refractory/threshold selects, comparing every state variable by its
//! raw bits (NaN-safe, unlike `==`). An engine-level test repeats the
//! comparison through the full simulation across 1/2/4 threads, and a
//! regression test pins the `gather_inputs` fix: negative-weight Poisson
//! drive must reach the network as inhibition (the seed dropped it).

use std::sync::Arc;

use cortex::atlas::{random_spec, random_spec_with};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::model::lif::{self, LifState, Propagators};
use cortex::model::{adex, hh};
use cortex::model::{
    AdexParams, AdexState, HhParams, HhState, LifParams, ModelParams,
    PoissonDrive,
};
use cortex::nest_baseline::{run_nest_simulation, NestRunConfig};
use cortex::util::proptest_lite::{property, Gen};

const DT_MS: f64 = 0.1;

fn bits_equal(name: &str, a: &[f64], b: &[f64]) -> Result<(), String> {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.to_bits() != y.to_bits() {
            return Err(format!(
                "{name}[{i}] diverged: scalar {x:?} vs vector {y:?}"
            ));
        }
    }
    Ok(())
}

/// Random inputs for one step of a block of `n` neurons: excitatory
/// bombardment (occasionally strong enough to force a spike and a
/// refractory period at these parameter ranges) plus inhibitory drive,
/// which since the `gather_inputs` fix arrives as negative `in_i`.
fn random_inputs(
    g: &mut Gen,
    n: usize,
    e_max: f64,
    i_min: f64,
) -> (Vec<f64>, Vec<f64>) {
    let hot = g.bool(0.3); // bombardment steps
    let scale = if hot { 1.0 } else { 0.2 };
    let in_e = (0..n).map(|_| g.f64(0.0, e_max) * scale).collect();
    let in_i = (0..n).map(|_| g.f64(i_min, 0.0) * scale).collect();
    (in_e, in_i)
}

#[test]
fn lif_vector_bit_identical_on_random_mixed_pidx_blocks() {
    property("lif vector == scalar", 150, |g| {
        // a few propagator sets with genuinely different dynamics, so
        // mixed-pidx spans exercise the homogeneous-run segmentation
        let n_props = g.usize(1..4);
        let props: Vec<Propagators> = (0..n_props)
            .map(|_| {
                Propagators::new(
                    &LifParams {
                        tau_m: g.f64(2.0, 30.0),
                        tau_syn_ex: g.f64(0.2, 3.0),
                        tau_syn_in: g.f64(0.2, 3.0),
                        v_th: g.f64(-55.0, -45.0),
                        t_ref: g.f64(0.0, 4.0),
                        i_ext: g.f64(0.0, 450.0),
                        ..Default::default()
                    },
                    DT_MS,
                )
            })
            .collect();
        // block sizes from 1 to three mask chunks (MASK_CHUNK = 64)
        let n = g.usize(1..200);
        let pidx: Vec<u8> = (0..n)
            .map(|_| g.u32(0..n_props as u32) as u8)
            .collect();
        let mut s = LifState::new(n, &props, pidx.clone());
        let mut v = LifState::new(n, &props, pidx);
        for _ in 0..g.usize(1..30) {
            let (in_e, in_i) = random_inputs(g, n, 900.0, -400.0);
            let (mut sp_s, mut sp_v) = (Vec::new(), Vec::new());
            lif::step_slice(&mut s, 0, n, &in_e, &in_i, &props, &mut sp_s);
            lif::step_slice_vector(
                &mut v, 0, n, &in_e, &in_i, &props, &mut sp_v,
            );
            if sp_s != sp_v {
                return Err(format!(
                    "spike lists diverged: {sp_s:?} vs {sp_v:?}"
                ));
            }
        }
        // one partial-span step (lo > 0), as the engine issues for
        // blocks that straddle worker boundaries
        if n > 1 {
            let lo = g.usize(0..n - 1);
            let hi = g.usize(lo + 1..n + 1);
            let (in_e, in_i) = random_inputs(g, hi - lo, 900.0, -400.0);
            let (mut sp_s, mut sp_v) = (Vec::new(), Vec::new());
            lif::step_slice(&mut s, lo, hi, &in_e, &in_i, &props, &mut sp_s);
            lif::step_slice_vector(
                &mut v, lo, hi, &in_e, &in_i, &props, &mut sp_v,
            );
            if sp_s != sp_v {
                return Err("partial-span spike lists diverged".into());
            }
        }
        bits_equal("u", &s.u, &v.u)?;
        bits_equal("ie", &s.ie, &v.ie)?;
        bits_equal("ii", &s.ii, &v.ii)?;
        bits_equal("refrac", &s.refrac, &v.refrac)
    });
}

#[test]
fn adex_vector_bit_identical_on_random_params() {
    property("adex vector == scalar", 100, |g| {
        let p = AdexParams {
            a: g.f64(0.0, 8.0),
            b: g.f64(0.0, 120.0),
            tau_w: g.f64(20.0, 300.0),
            delta_t: g.f64(0.5, 3.0),
            t_ref: g.f64(0.0, 4.0),
            i_ext: g.f64(0.0, 700.0),
            ..Default::default()
        };
        let n = g.usize(1..200);
        let mut s = AdexState::new(n, &p);
        let mut v = AdexState::new(n, &p);
        for _ in 0..g.usize(1..40) {
            let (in_e, in_i) = random_inputs(g, n, 800.0, -500.0);
            let (mut sp_s, mut sp_v) = (Vec::new(), Vec::new());
            adex::step_slice(
                &mut s, 0, n, &in_e, &in_i, &p, DT_MS, &mut sp_s,
            );
            adex::step_slice_vector(
                &mut v, 0, n, &in_e, &in_i, &p, DT_MS, &mut sp_v,
            );
            if sp_s != sp_v {
                return Err(format!(
                    "spike lists diverged: {sp_s:?} vs {sp_v:?}"
                ));
            }
        }
        bits_equal("v", &s.v, &v.v)?;
        bits_equal("w", &s.w, &v.w)?;
        bits_equal("ie", &s.ie, &v.ie)?;
        bits_equal("ii", &s.ii, &v.ii)?;
        bits_equal("refrac", &s.refrac, &v.refrac)
    });
}

#[test]
fn hh_vector_bit_identical_on_random_params() {
    property("hh vector == scalar", 40, |g| {
        let p = HhParams {
            i_ext: g.f64(0.0, 12.0),
            tau_syn_ex: g.f64(0.2, 3.0),
            tau_syn_in: g.f64(0.2, 6.0),
            ..Default::default()
        };
        let n = g.usize(1..150);
        let mut s = HhState::new(n);
        let mut v = HhState::new(n);
        for _ in 0..g.usize(1..15) {
            let (in_e, in_i) = random_inputs(g, n, 60.0, -40.0);
            let (mut sp_s, mut sp_v) = (Vec::new(), Vec::new());
            hh::step_slice(
                &mut s, 0, n, &in_e, &in_i, &p, DT_MS, &mut sp_s,
            );
            hh::step_slice_vector(
                &mut v, 0, n, &in_e, &in_i, &p, DT_MS, &mut sp_v,
            );
            if sp_s != sp_v {
                return Err(format!(
                    "spike lists diverged: {sp_s:?} vs {sp_v:?}"
                ));
            }
        }
        bits_equal("v", &s.v, &v.v)?;
        bits_equal("m", &s.m, &v.m)?;
        bits_equal("h", &s.h, &v.h)?;
        bits_equal("n", &s.n, &v.n)?;
        bits_equal("v_prev", &s.v_prev, &v.v_prev)?;
        bits_equal("ie", &s.ie, &v.ie)?;
        bits_equal("ii", &s.ii, &v.ii)
    });
}

// ---------------------------------------------------------------------
// Through the full engine
// ---------------------------------------------------------------------

fn cfg(threads: usize, integrate: IntegrateMode, seed: u64) -> RunConfig {
    RunConfig {
        ranks: 1,
        threads,
        mapping: MappingKind::AreaProcesses,
        comm: CommMode::Overlap,
        backend: DynamicsBackend::Native,
        exec: ExecMode::Pool,
        build: BuildMode::TwoPass,
        integrate,
        routing: RoutingMode::Routed,
        steps: 300,
        record_limit: Some(u32::MAX),
        verify_ownership: true,
        artifacts_dir: "artifacts".into(),
        seed,
    }
}

#[test]
fn engine_raster_identical_scalar_vs_vector_across_threads() {
    // mixed AdEx/LIF balanced random network: both kernel families run
    // in the same simulation, under real Poisson drive and real worker
    // partitions, at every thread count
    let spec = Arc::new(random_spec_with(
        400,
        40,
        7,
        ModelParams::Adex(AdexParams {
            i_ext: 700.0,
            ..Default::default()
        }),
        ModelParams::Lif(LifParams::default()),
    ));
    let mut reference = None;
    for integrate in [IntegrateMode::Scalar, IntegrateMode::Vector] {
        for threads in [1usize, 2, 4] {
            let out =
                run_simulation(&spec, &cfg(threads, integrate, 7)).unwrap();
            assert!(
                out.total_spikes > 0,
                "network inactive ({integrate:?}, {threads}t)"
            );
            if let Some(want) = &reference {
                assert_eq!(
                    want, &out.raster.events,
                    "{integrate:?} at {threads} threads changed the raster"
                );
            } else {
                reference = Some(out.raster.events);
            }
        }
    }
}

#[test]
fn negative_weight_poisson_drive_inhibits_the_network() {
    // regression for the seed's gather_inputs, which silently dropped
    // drives with negative weight: an inhibitory drive behaved exactly
    // like no drive at all
    let mk = |weight_pa: f64| {
        let mut spec = random_spec(300, 30, 13);
        // re-purpose the I population's drive as inhibitory bombardment
        spec.populations[1].drive = PoissonDrive::new(8000.0, weight_pa);
        Arc::new(spec)
    };
    let run = |weight_pa: f64| {
        run_simulation(&mk(weight_pa), &cfg(1, IntegrateMode::Vector, 13))
            .unwrap()
    };
    let inhibited = run(-60.0);
    let undriven = run(0.0); // weight 0 ⇒ drive off
    assert!(inhibited.total_spikes > 0, "network should stay active");
    assert_ne!(
        inhibited.raster.events, undriven.raster.events,
        "negative-weight drive must reach the network as inhibition"
    );
    // the reference backend routes the same drive the same way, so the
    // rasters agree spike-for-spike on the inhibited network
    let nest = run_nest_simulation(
        &mk(-60.0),
        &NestRunConfig {
            ranks: 1,
            threads: 1,
            steps: 300,
            record_limit: Some(u32::MAX),
            seed: 13,
        },
    );
    assert_eq!(
        inhibited.raster.events, nest.raster.events,
        "engine and baseline disagree on inhibitory drive"
    );
}
