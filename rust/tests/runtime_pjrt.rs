//! Runtime integration: load the AOT artifacts (JAX/Pallas → HLO text →
//! PJRT CPU) and check the compiled kernel agrees with the native Rust
//! step to f64 round-off, then run a whole simulation on the PJRT
//! backend and compare against the native backend.
//!
//! Requires `make artifacts`; tests skip (with a message) if absent.

use std::sync::Arc;

use cortex::atlas::random_spec;
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::model::lif::{step_slice, LifParams, LifState, Propagators};
use cortex::model::ModelParams;
use cortex::runtime::{HloExecutable, Manifest, PjrtLif};
use cortex::util::rng::Rng;

fn artifacts() -> Option<&'static std::path::Path> {
    let p = std::path::Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_loads() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(dir).unwrap();
    assert!(!m.lif_sizes.is_empty());
    let (p22, ..) = m.propagators().unwrap();
    assert!(p22 > 0.0 && p22 < 1.0);
}

#[test]
fn hlo_executable_compiles_on_cpu() {
    let Some(dir) = artifacts() else { return };
    let m = Manifest::load(dir).unwrap();
    let name = format!("lif_step_n{}", m.lif_sizes[0]);
    let exe = HloExecutable::load(dir, &name).unwrap();
    assert_eq!(exe.platform().to_lowercase(), "cpu");
}

#[test]
fn pjrt_step_matches_native_step() {
    let Some(_) = artifacts() else { return };
    let spec = Arc::new(random_spec(700, 10, 3));
    let mut pjrt = PjrtLif::load("artifacts", &spec).unwrap();

    let params = LifParams::default();
    let props = [Propagators::new(&params, 0.1)];
    let n = 700; // forces padding (block is 512 or 2048)
    let mut rng = Rng::new(42);
    let mut native = LifState::new(n, &props, vec![0; n]);
    let mut accel = LifState::new(n, &props, vec![0; n]);
    for i in 0..n {
        let u = params.e_l + rng.range_f64(0.0, 16.0);
        native.u[i] = u;
        accel.u[i] = u;
        let ie = rng.range_f64(0.0, 300.0);
        native.ie[i] = ie;
        accel.ie[i] = ie;
    }

    for step in 0..50 {
        let in_e: Vec<f64> =
            (0..n).map(|_| rng.range_f64(0.0, 120.0)).collect();
        let in_i: Vec<f64> =
            (0..n).map(|_| -rng.range_f64(0.0, 120.0)).collect();
        let mut native_spikes = Vec::new();
        step_slice(
            &mut native, 0, n, &in_e, &in_i, &props, &mut native_spikes,
        );
        let accel_spikes =
            pjrt.step(&mut accel, &in_e, &in_i).unwrap();
        assert_eq!(
            native_spikes, accel_spikes,
            "spike sets diverged at step {step}"
        );
        for i in 0..n {
            assert!(
                (native.u[i] - accel.u[i]).abs() < 1e-10,
                "step {step} neuron {i}: u {} vs {}",
                native.u[i],
                accel.u[i]
            );
            assert!((native.ie[i] - accel.ie[i]).abs() < 1e-10);
            assert_eq!(native.refrac[i], accel.refrac[i]);
        }
    }
}

#[test]
fn pjrt_backend_full_simulation_matches_native() {
    let Some(_) = artifacts() else { return };
    let spec = Arc::new(random_spec(300, 30, 5));
    let cfg = RunConfig {
        ranks: 1,
        threads: 1,
        mapping: MappingKind::AreaProcesses,
        comm: CommMode::Serialized,
        backend: DynamicsBackend::Native,
        exec: ExecMode::Pool,
        build: BuildMode::TwoPass,
        integrate: IntegrateMode::Vector,
        routing: RoutingMode::Routed,
        comm_group: Vec::new(),
        steps: 400,
        record_limit: Some(u32::MAX),
        verify_ownership: false,
        artifacts_dir: "artifacts".into(),
        seed: 77,
    };
    let native = run_simulation(&spec, &cfg).unwrap();
    let mut cfg2 = cfg.clone();
    cfg2.backend = DynamicsBackend::Pjrt;
    let accel = run_simulation(&spec, &cfg2).unwrap();
    assert!(native.total_spikes > 0);
    assert_eq!(
        native.raster.events, accel.raster.events,
        "PJRT and native backends must agree spike-for-spike"
    );
}

#[test]
fn pjrt_rejects_mismatched_parameters() {
    let Some(_) = artifacts() else { return };
    let mut spec = random_spec(100, 10, 6);
    spec.params[0] = ModelParams::Lif(LifParams {
        tau_m: 17.0, // not what the artifact baked
        ..LifParams::default()
    });
    let err = PjrtLif::load("artifacts", &spec);
    assert!(err.is_err(), "must reject mismatched parameters");
}
