//! The spike wire codec as a trust boundary, plus the TCP rank runtime
//! end to end.
//!
//! Adversarial property tests (via `util::proptest_lite`): random spike
//! windows round-trip bit-exactly through `bsb::pack`/`unpack` and the
//! framed `encode_frame`/`decode_frame`, while random, truncated and
//! bit-flipped byte strings only ever produce `CodecError`s — never
//! panics. Then the acceptance criterion of the distributed runtime:
//! a 2-rank Potjans run over `TcpComm` on localhost produces a spike
//! raster **bit-identical** to the same spec/seed/threads run over
//! `LocalComm`, in both `serialized` and `overlap` comm modes.
//!
//! The serve control protocol (`serve::proto`) is the same kind of
//! trust boundary and gets the same adversarial treatment; and the
//! subscription collective's edge cases — a rank that subscribes to
//! nothing (zero-edge network) and a single-rank cluster — are pinned
//! over both transports.

use std::io::Cursor;
use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::atlas::potjans::potjans_spec;
use cortex::comm::bsb::{self, CodecError, MergedEntry};
use cortex::comm::{
    CommError, CommGroups, Communicator, HierarchicalComm,
    LocalCluster, Outbound, SpikeMsg, TcpComm, MAX_FRAME_BYTES,
};
use cortex::config::{
    BuildMode, CommMode, ConfigDoc, DynamicsBackend, ExecMode,
    ExperimentConfig, IntegrateMode, MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig, Simulation};
use cortex::probe::ProbeData;
use cortex::serve::proto::{self, ProtoError};
use cortex::serve::{
    AdmissionError, ProbeSpec, Reply, Request, ServeStats,
};
use cortex::util::proptest_lite::{property, Gen};

fn random_window(g: &mut Gen) -> (u32, Vec<SpikeMsg>) {
    let start = g.u32(0..1_000_000);
    let len = g.u32(1..30);
    let n = g.usize(0..200);
    let spikes = (0..n)
        .map(|_| SpikeMsg {
            gid: g.u32(0..200_000),
            step: start + g.u32(0..len),
        })
        .collect();
    (start, spikes)
}

#[test]
fn random_windows_roundtrip_exactly() {
    property("pack/unpack roundtrip", 200, |g| {
        let (start, spikes) = random_window(g);
        let buf = bsb::pack(start, &spikes)
            .map_err(|e| format!("pack failed: {e}"))?;
        let got = bsb::unpack(start, &buf)
            .map_err(|e| format!("unpack failed: {e}"))?;
        let mut want = spikes.clone();
        want.sort_unstable_by_key(|m| (m.step, m.gid));
        if got != want {
            return Err(format!(
                "mismatch: {} in, {} out",
                want.len(),
                got.len()
            ));
        }
        // the framed form carries the window counter alongside
        let window = g.usize(0..1_000_000) as u64;
        let frame = bsb::encode_frame(window, &spikes)
            .map_err(|e| format!("encode_frame failed: {e}"))?;
        let (w, got) = bsb::decode_frame(&frame)
            .map_err(|e| format!("decode_frame failed: {e}"))?;
        let mut got = got;
        got.sort_unstable_by_key(|m| (m.step, m.gid));
        if w != window || got != want {
            return Err("frame roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn random_bytes_never_panic_only_error() {
    property("garbage decode is total", 500, |g| {
        let n = g.usize(0..200);
        let bytes: Vec<u8> =
            (0..n).map(|_| g.u32(0..256) as u8).collect();
        let start = g.u32(0..1_000_000);
        // any outcome is fine as long as it is a returned value
        let _ = bsb::unpack(start, &bytes);
        let _ = bsb::decode_frame(&bytes);
        Ok(())
    });
}

#[test]
fn every_truncation_of_a_valid_packet_errors() {
    property("truncations error out", 100, |g| {
        let (start, mut spikes) = random_window(g);
        if spikes.is_empty() {
            spikes.push(SpikeMsg { gid: 7, step: start });
        }
        let buf = bsb::pack(start, &spikes)
            .map_err(|e| format!("pack failed: {e}"))?;
        for cut in 0..buf.len() {
            if bsb::unpack(start, &buf[..cut]).is_ok() {
                return Err(format!(
                    "prefix of {cut}/{} bytes decoded successfully",
                    buf.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn bit_flips_never_panic() {
    property("bit flips are total", 200, |g| {
        let (start, spikes) = random_window(g);
        let window = g.usize(0..1000) as u64;
        let mut frame = bsb::encode_frame(window, &spikes)
            .map_err(|e| format!("encode_frame failed: {e}"))?;
        let byte = g.usize(0..frame.len());
        let bit = g.u32(0..8);
        frame[byte] ^= 1 << bit;
        // a flipped frame may still decode (to different spikes) or
        // error — it must only never panic
        let _ = bsb::decode_frame(&frame);
        let _ = bsb::unpack(start, &frame);
        Ok(())
    });
}

#[test]
fn overlong_varint_is_rejected() {
    let buf = vec![0xffu8; 16];
    assert_eq!(bsb::unpack(0, &buf), Err(CodecError::VarintOverflow));
    assert!(bsb::decode_frame(&buf).is_err());
}

// ---------------------------------------------------------------------
// TCP rank runtime: bit-identity against the in-memory transport
// ---------------------------------------------------------------------

const SCALE: f64 = 1600.0 / 77_169.0;
const SEED: u64 = 23;
const STEPS: u64 = 600;
const THREADS: usize = 2;

fn local_run(
    spec: &Arc<cortex::atlas::NetworkSpec>,
    comm: CommMode,
    ranks: usize,
    routing: RoutingMode,
) -> cortex::engine::RunOutput {
    run_simulation(
        spec,
        &RunConfig {
            ranks,
            threads: THREADS,
            mapping: MappingKind::AreaProcesses,
            comm,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing,
            comm_group: Vec::new(),
            steps: STEPS,
            record_limit: Some(u32::MAX),
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed: SEED,
        },
    )
    .unwrap()
}

fn local_raster(
    spec: &Arc<cortex::atlas::NetworkSpec>,
    comm: CommMode,
) -> Vec<(u64, u32)> {
    local_run(spec, comm, 2, RoutingMode::Routed).raster.events
}

/// Run the same 2-rank simulation as two single-rank TCP sessions (one
/// per thread, real sockets on ephemeral localhost ports), driving
/// each through the given `run_for` chunks, and merge their rasters.
fn tcp_raster_matrix(
    spec: &Arc<cortex::atlas::NetworkSpec>,
    comm: CommMode,
    chunks: &[u64],
    ranks: usize,
    routing: RoutingMode,
) -> Vec<(u64, u32)> {
    let listeners: Vec<TcpListener> = (0..ranks)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    let peers: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(rank, listener)| {
            let spec = Arc::clone(spec);
            let peers = peers.clone();
            let chunks = chunks.to_vec();
            thread::spawn(move || {
                let endpoint = TcpComm::join_with_listener(
                    rank as u16,
                    listener,
                    &peers,
                    Duration::from_secs(30),
                )
                .unwrap();
                let mut sim = Simulation::builder(spec)
                    .ranks(ranks)
                    .threads(THREADS)
                    .mapping(MappingKind::AreaProcesses)
                    .comm(comm)
                    .routing(routing)
                    .record_limit(Some(u32::MAX))
                    .seed(SEED)
                    .transport_with(move |n| {
                        assert_eq!(n, ranks);
                        Ok(vec![(
                            rank,
                            Box::new(endpoint)
                                as Box<dyn Communicator>,
                        )])
                    })
                    .build()
                    .unwrap();
                for steps in chunks {
                    sim.run_for(steps).unwrap();
                }
                let out = sim.finish().unwrap();
                out.raster.events
            })
        })
        .collect();
    let mut events = Vec::new();
    for h in handles {
        events.extend(h.join().unwrap());
    }
    events.sort_unstable();
    events
}

fn tcp_raster(
    spec: &Arc<cortex::atlas::NetworkSpec>,
    comm: CommMode,
    chunks: &[u64],
) -> Vec<(u64, u32)> {
    tcp_raster_matrix(spec, comm, chunks, 2, RoutingMode::Routed)
}

#[test]
fn tcp_two_rank_potjans_raster_bit_identical_to_local() {
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    for comm in [CommMode::Serialized, CommMode::Overlap] {
        let want = local_raster(&spec, comm);
        assert!(
            !want.is_empty(),
            "{comm:?}: microcircuit should be active"
        );
        let got = tcp_raster(&spec, comm, &[STEPS]);
        assert_eq!(
            got, want,
            "{comm:?}: TCP transport changed the raster \
             ({} vs {} events)",
            got.len(),
            want.len()
        );
    }
}

#[test]
fn tcp_split_runs_stay_aligned_across_windows() {
    // run_for in uneven chunks (including mid-window stops) over TCP:
    // the per-window frame counters must stay aligned and the merged
    // raster identical to one combined local run. 7 + 100 + 493 = 600.
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    let want = local_raster(&spec, CommMode::Overlap);
    let got = tcp_raster(&spec, CommMode::Overlap, &[7, 100, 493]);
    assert_eq!(got, want, "split TCP runs diverged from local");
}

// ---------------------------------------------------------------------
// Interest routing: bit-identity to broadcast + wire-volume reduction
// ---------------------------------------------------------------------

#[test]
fn routed_is_bit_identical_to_broadcast_across_the_local_matrix() {
    // the full local matrix: 2/4 ranks × serialized/overlap. Routed
    // exchange must reproduce the broadcast raster bit-for-bit — it
    // only withholds spikes the receiver's sub-graph would have
    // dropped on enqueue anyway. No volume reduction is expected HERE:
    // the single-area microcircuit is recurrently dense, so at these
    // rank counts every rank subscribes to (essentially) every peer
    // gid and routed volume rides at the broadcast bound — which is
    // itself part of the contract: routing must never *add* bytes.
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    for ranks in [2usize, 4] {
        for comm in [CommMode::Serialized, CommMode::Overlap] {
            let bcast =
                local_run(&spec, comm, ranks, RoutingMode::Broadcast);
            assert!(
                !bcast.raster.events.is_empty(),
                "{ranks}r/{comm:?}: microcircuit should be active"
            );
            let routed =
                local_run(&spec, comm, ranks, RoutingMode::Routed);
            assert_eq!(
                routed.raster.events, bcast.raster.events,
                "{ranks}r/{comm:?}: routed exchange changed the raster"
            );
            assert_eq!(
                routed.total_spikes, bcast.total_spikes,
                "{ranks}r/{comm:?}: spike totals diverged"
            );
            // closed cluster: every byte sent is a byte received
            assert_eq!(routed.comm_bytes, routed.comm_recv_bytes);
            assert_eq!(bcast.comm_bytes, bcast.comm_recv_bytes);
            assert!(
                routed.comm_bytes <= bcast.comm_bytes,
                "{ranks}r/{comm:?}: routed {} > broadcast {}",
                routed.comm_bytes,
                bcast.comm_bytes
            );
        }
    }
}

#[test]
fn routed_sheds_wire_volume_on_the_multi_area_network() {
    // where the reduction structurally lives (paper Fig 7/8: varied
    // density of synaptic interactions): in the multi-area model,
    // inhibitory populations project only within their own area, so
    // with area-aligned ranks no rank ever subscribes to a remote I
    // gid — every inhibitory spike stays off the wire — and
    // distance-decayed E→E pairs whose indegree rounds to zero drop
    // whole remote areas. Identity still holds bit-for-bit.
    let spec = Arc::new(marmoset_spec(
        &MarmosetParams {
            n_neurons: 3_000,
            n_areas: 8,
            indegree: 150,
            ..Default::default()
        },
        SEED,
    ));
    let bcast = local_run(&spec, CommMode::Overlap, 4, RoutingMode::Broadcast);
    assert!(
        !bcast.raster.events.is_empty(),
        "multi-area network should be active"
    );
    let routed = local_run(&spec, CommMode::Overlap, 4, RoutingMode::Routed);
    assert_eq!(
        routed.raster.events, bcast.raster.events,
        "routed exchange changed the multi-area raster"
    );
    // ≥ 1/5 of every area is inhibitory and never subscribed remotely,
    // so the routed share must come in measurably below broadcast
    assert!(
        (routed.comm_bytes as f64)
            < 0.95 * bcast.comm_bytes as f64,
        "no measurable reduction: routed {} vs broadcast {}",
        routed.comm_bytes,
        bcast.comm_bytes
    );
}

#[test]
fn routed_is_bit_identical_to_broadcast_over_tcp() {
    // sockets exercise the framed codec + the nonblocking interleaved
    // exchange loop; 2 ranks across both comm modes, then 4 ranks
    // under overlap (the production shape)
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    for (ranks, comm) in [
        (2usize, CommMode::Serialized),
        (2, CommMode::Overlap),
        (4, CommMode::Overlap),
    ] {
        let want = tcp_raster_matrix(
            &spec,
            comm,
            &[STEPS],
            ranks,
            RoutingMode::Broadcast,
        );
        assert!(
            !want.is_empty(),
            "{ranks}r/{comm:?}: microcircuit should be active"
        );
        let got = tcp_raster_matrix(
            &spec,
            comm,
            &[STEPS],
            ranks,
            RoutingMode::Routed,
        );
        assert_eq!(
            got, want,
            "{ranks}r/{comm:?}: routed TCP exchange changed the \
             raster ({} vs {} events)",
            got.len(),
            want.len()
        );
    }
}

#[test]
fn routed_checkpoints_are_bit_identical_to_broadcast() {
    // the session checkpoint serializes every rank's full dynamical
    // state — bit-equal blobs mean the two routing modes agree on
    // every membrane potential, queue entry and RNG draw, not just on
    // the recorded raster
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    let blob_of = |routing: RoutingMode| {
        let mut sim = Simulation::builder(Arc::clone(&spec))
            .ranks(2)
            .threads(THREADS)
            .comm(CommMode::Overlap)
            .routing(routing)
            .record_limit(Some(u32::MAX))
            .seed(SEED)
            .build()
            .unwrap();
        sim.run_for(300).unwrap();
        let mut blob = Vec::new();
        sim.checkpoint(&mut blob).unwrap();
        sim.finish().unwrap();
        blob
    };
    let routed = blob_of(RoutingMode::Routed);
    let bcast = blob_of(RoutingMode::Broadcast);
    assert!(!routed.is_empty());
    assert_eq!(
        routed, bcast,
        "routing mode leaked into the checkpointed state"
    );
}

// ---------------------------------------------------------------------
// Hierarchical exchange: bit-identity across the rank × transport ×
// comm-mode matrix, merged-frame reduction, and failure surfaces
// ---------------------------------------------------------------------

#[test]
fn hierarchical_is_bit_identical_across_the_local_matrix() {
    // 2/4/8 ranks × serialized/overlap: the two-level relay protocol
    // must reproduce the flat routed raster bit-for-bit (the receiver
    // re-sorts merged sub-frames into source-rank order, so delivery
    // is indistinguishable), while collapsing the per-window frame
    // count at ≥ 4 ranks (2 ranks = one group = no relay round, same
    // two frames either way)
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    for ranks in [2usize, 4, 8] {
        for comm in [CommMode::Serialized, CommMode::Overlap] {
            let routed =
                local_run(&spec, comm, ranks, RoutingMode::Routed);
            assert!(
                !routed.raster.events.is_empty(),
                "{ranks}r/{comm:?}: microcircuit should be active"
            );
            let hier = local_run(
                &spec,
                comm,
                ranks,
                RoutingMode::Hierarchical,
            );
            assert_eq!(
                hier.raster.events, routed.raster.events,
                "{ranks}r/{comm:?}: hierarchical exchange changed \
                 the raster"
            );
            assert_eq!(hier.total_spikes, routed.total_spikes);
            // closed cluster: every byte sent is a byte received
            assert_eq!(hier.comm_bytes, hier.comm_recv_bytes);
            if ranks >= 4 {
                assert!(
                    hier.comm_frames < routed.comm_frames,
                    "{ranks}r/{comm:?}: merged frames {} not below \
                     flat mesh {}",
                    hier.comm_frames,
                    routed.comm_frames
                );
            } else {
                assert_eq!(hier.comm_frames, routed.comm_frames);
            }
            // the overlap ratio is a share of hidden exchange time;
            // serialized mode by definition hides nothing
            assert!(
                (0.0..=1.0).contains(&hier.comm_overlap_ratio),
                "ratio {} out of range",
                hier.comm_overlap_ratio
            );
            if comm == CommMode::Serialized {
                assert_eq!(hier.comm_overlap_ratio, 0.0);
            }
        }
    }
}

#[test]
fn hierarchical_is_bit_identical_over_tcp() {
    // real sockets: gather/scatter and the relay↔relay merged frames
    // ride the TCP point-to-point frame path (one rank per endpoint,
    // so there is no in-process fast path to hide behind); uneven
    // run_for chunks at 4 ranks exercise mid-window stops against the
    // double-buffered staging
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    let chunks: &[u64] = &[STEPS];
    let split: &[u64] = &[7, 100, 493];
    for (ranks, comm, chunks) in [
        (2usize, CommMode::Serialized, chunks),
        (2, CommMode::Overlap, chunks),
        (4, CommMode::Serialized, chunks),
        (4, CommMode::Overlap, split),
        (8, CommMode::Overlap, chunks),
    ] {
        let want = local_run(&spec, comm, ranks, RoutingMode::Routed)
            .raster
            .events;
        let got = tcp_raster_matrix(
            &spec,
            comm,
            chunks,
            ranks,
            RoutingMode::Hierarchical,
        );
        assert_eq!(
            got, want,
            "{ranks}r/{comm:?}: hierarchical TCP exchange changed \
             the raster ({} vs {} events)",
            got.len(),
            want.len()
        );
    }
}

#[test]
fn hierarchical_checkpoints_are_bit_identical_to_routed() {
    // bit-equal checkpoint blobs mean the relay protocol agrees with
    // the flat mesh on every membrane potential, queue entry and RNG
    // draw — not just on the recorded raster
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    let blob_of = |routing: RoutingMode| {
        let mut sim = Simulation::builder(Arc::clone(&spec))
            .ranks(4)
            .threads(THREADS)
            .comm(CommMode::Overlap)
            .routing(routing)
            .record_limit(Some(u32::MAX))
            .seed(SEED)
            .build()
            .unwrap();
        sim.run_for(300).unwrap();
        let mut blob = Vec::new();
        sim.checkpoint(&mut blob).unwrap();
        sim.finish().unwrap();
        blob
    };
    let hier = blob_of(RoutingMode::Hierarchical);
    let routed = blob_of(RoutingMode::Routed);
    assert!(!hier.is_empty());
    assert_eq!(
        hier, routed,
        "hierarchical routing leaked into the checkpointed state"
    );
}

#[test]
fn merged_frame_garbage_never_panics_only_typed_errors() {
    property("merged garbage decode is total", 500, |g| {
        let n = g.usize(0..200);
        let bytes: Vec<u8> =
            (0..n).map(|_| g.u32(0..256) as u8).collect();
        // any outcome is fine as long as it is a returned value
        let _ = bsb::decode_merged(&bytes);
        Ok(())
    });
}

/// Four TCP ranks under hierarchical routing (groups {0,1} / {2,3});
/// `casualty` completes one window exchange and then drops its
/// endpoint cold. Every survivor must surface a typed
/// [`CommError::PeerLost`] from whatever protocol round it was blocked
/// in — never a panic, never a hang. The loss reaches each rank
/// mid-window: the adjacent rank fails its gather or relay round, its
/// own teardown then cascades the error to the rest of the cluster.
fn hier_tcp_peer_loss(casualty: usize) {
    let ranks = 4usize;
    let groups = CommGroups::even(ranks, 2);
    let listeners: Vec<TcpListener> = (0..ranks)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    let peers: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(rank, listener)| {
            let peers = peers.clone();
            let groups = groups.clone();
            thread::spawn(move || {
                let tcp = TcpComm::join_with_listener(
                    rank as u16,
                    listener,
                    &peers,
                    Duration::from_secs(30),
                )
                .unwrap();
                let mut comm =
                    HierarchicalComm::new(Box::new(tcp), groups)
                        .unwrap();
                let windows = if rank == casualty { 1 } else { 3 };
                let mut err = None;
                for _ in 0..windows {
                    let out = Outbound::Routed(
                        (0..ranks)
                            .map(|d| {
                                if d == rank {
                                    Vec::new()
                                } else {
                                    vec![SpikeMsg {
                                        gid: rank as u32,
                                        step: 0,
                                    }]
                                }
                            })
                            .collect(),
                    );
                    match comm.exchange_outbound(out) {
                        Ok(_) => {}
                        Err(e) => {
                            err = Some(e);
                            break;
                        }
                    }
                }
                (rank, err)
            })
        })
        .collect();
    for h in handles {
        let (rank, err) = h.join().unwrap();
        if rank == casualty {
            assert!(
                err.is_none(),
                "casualty rank {rank} should exit clean: {err:?}"
            );
        } else {
            match err {
                Some(CommError::PeerLost { .. }) => {}
                other => panic!(
                    "rank {rank}: expected PeerLost, got {other:?}"
                ),
            }
        }
    }
}

#[test]
fn member_loss_mid_window_surfaces_peer_lost_on_every_survivor() {
    // rank 3 is a plain member: its relay fails the gather round
    hier_tcp_peer_loss(3);
}

#[test]
fn relay_loss_mid_window_surfaces_peer_lost_on_every_survivor() {
    // rank 2 relays group 1: its member and the partner relay both
    // lose their counterpart mid-protocol
    hier_tcp_peer_loss(2);
}

#[test]
fn window_mismatch_inside_a_merged_frame_is_a_typed_error() {
    // a member that desyncs its window counter must be refused with
    // the counters in the error, not have its spikes delivered into
    // the wrong window
    let mut comms = LocalCluster::new(2);
    let mut member = comms.pop().unwrap(); // rank 1
    let relay = comms.pop().unwrap(); // rank 0
    let groups = CommGroups::new(vec![0, 0]).unwrap();
    let mut relay =
        HierarchicalComm::new(Box::new(relay), groups).unwrap();
    let entries = vec![MergedEntry {
        source: 1,
        dest: 0,
        spikes: vec![SpikeMsg { gid: 9, step: 0 }],
    }];
    // stamped with window 7 while the relay is at window 0
    let frame =
        bsb::encode_merged(7, &entries, MAX_FRAME_BYTES).unwrap();
    member.send_frame(0, &frame).unwrap();
    let err = relay
        .exchange_outbound(Outbound::Routed(vec![
            Vec::new(),
            Vec::new(),
        ]))
        .unwrap_err();
    assert!(
        matches!(err, CommError::WindowMismatch { got: 7, want: 0 }),
        "expected WindowMismatch, got {err:?}"
    );
}

// ---------------------------------------------------------------------
// Subscription collective edge cases: zero-subscription ranks and the
// single-rank cluster, over both transports
// ---------------------------------------------------------------------

/// A custom network with `indegree = 0`: zero recurrent edges, every
/// neuron driven only by its background Poisson source. No rank
/// subscribes to any remote gid, so the delta-coded subscription
/// lists exchanged at build time are all empty.
fn zero_edge_spec() -> Arc<cortex::atlas::NetworkSpec> {
    let mut doc = ConfigDoc::parse("").unwrap();
    doc.apply_overrides(&[
        "network.kind=\"custom\"".to_string(),
        "network.indegree=0".to_string(),
        "network.populations=[\"E:240:lif:e\", \"I:60:lif:i\"]"
            .to_string(),
        "seed=11".to_string(),
    ])
    .unwrap();
    let cfg = ExperimentConfig::from_doc(&doc).unwrap();
    Arc::new(cortex::cli::build_spec(&cfg))
}

#[test]
fn zero_subscription_ranks_agree_with_broadcast() {
    let spec = zero_edge_spec();
    assert_eq!(spec.n_edges(), 0, "indegree 0 must build no edges");
    let bcast =
        local_run(&spec, CommMode::Overlap, 2, RoutingMode::Broadcast);
    assert!(
        !bcast.raster.events.is_empty(),
        "background Poisson should still drive spikes"
    );
    let routed =
        local_run(&spec, CommMode::Overlap, 2, RoutingMode::Routed);
    assert_eq!(
        routed.raster.events, bcast.raster.events,
        "empty subscription lists changed the raster"
    );
    // nothing is subscribed, so routing must strip every spike off
    // the wire that broadcast would have shipped
    assert!(
        routed.comm_bytes <= bcast.comm_bytes,
        "routed {} > broadcast {}",
        routed.comm_bytes,
        bcast.comm_bytes
    );
    // and the same exchange must survive real sockets
    let tcp = tcp_raster_matrix(
        &spec,
        CommMode::Overlap,
        &[STEPS],
        2,
        RoutingMode::Routed,
    );
    assert_eq!(
        tcp, bcast.raster.events,
        "zero-subscription TCP exchange changed the raster"
    );
}

#[test]
fn single_rank_cluster_runs_over_local_and_tcp() {
    // ranks = 1: the subscription collective has no peers to exchange
    // with, and the TCP transport must come up as a size-1 cluster
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    let routed =
        local_run(&spec, CommMode::Overlap, 1, RoutingMode::Routed);
    assert!(!routed.raster.events.is_empty());
    let bcast =
        local_run(&spec, CommMode::Overlap, 1, RoutingMode::Broadcast);
    assert_eq!(
        routed.raster.events, bcast.raster.events,
        "routing mode matters on a single rank"
    );
    let tcp = tcp_raster_matrix(
        &spec,
        CommMode::Overlap,
        &[STEPS],
        1,
        RoutingMode::Routed,
    );
    assert_eq!(
        tcp, routed.raster.events,
        "single-rank TCP cluster diverged from local"
    );
}

// ---------------------------------------------------------------------
// Serve control protocol: adversarial fuzzing of the second codec
// ---------------------------------------------------------------------

fn ascii(g: &mut Gen) -> String {
    let n = g.usize(0..12);
    (0..n).map(|_| (g.u32(32..127) as u8) as char).collect()
}

fn sid(g: &mut Gen) -> u64 {
    g.usize(0..1_000_000) as u64
}

fn random_probe_spec(g: &mut Gen) -> ProbeSpec {
    match g.u32(0..3) {
        0 => ProbeSpec::Raster { name: ascii(g) },
        1 => ProbeSpec::Rates {
            name: ascii(g),
            bin_steps: g.usize(1..1000) as u64,
        },
        _ => ProbeSpec::Phases { name: ascii(g) },
    }
}

fn random_probe_data(g: &mut Gen) -> ProbeData {
    match g.u32(0..4) {
        0 => ProbeData::Raster(
            (0..g.usize(0..20))
                .map(|_| (sid(g), g.u32(0..100_000)))
                .collect(),
        ),
        1 => ProbeData::Rates {
            bin_steps: g.usize(1..100) as u64,
            pops: (0..g.usize(0..4)).map(|_| ascii(g)).collect(),
            rows: (0..g.usize(0..6))
                .map(|_| {
                    let row = (0..g.usize(0..4))
                        .map(|_| g.f64(0.0, 50.0))
                        .collect();
                    (sid(g), row)
                })
                .collect(),
        },
        2 => ProbeData::Phases(
            (0..g.usize(0..6))
                .map(|_| {
                    (g.u32(0..8) as u16, ascii(g), g.f64(0.0, 9.0))
                })
                .collect(),
        ),
        _ => ProbeData::Lines(
            (0..g.usize(0..5)).map(|_| ascii(g)).collect(),
        ),
    }
}

fn random_serve_request(g: &mut Gen) -> Request {
    match g.u32(0..10) {
        0 => Request::Create {
            doc: ascii(g),
            overrides: (0..g.usize(0..4)).map(|_| ascii(g)).collect(),
            probes: (0..g.usize(0..3))
                .map(|_| random_probe_spec(g))
                .collect(),
        },
        1 => Request::Run {
            session: sid(g),
            steps: sid(g),
            push: g.bool(0.5),
        },
        2 => Request::Drain { session: sid(g), probe: ascii(g) },
        3 => Request::Poisson {
            session: sid(g),
            pop: ascii(g),
            rate_hz: g.f64(0.0, 20_000.0),
            weight_pa: g.f64(-500.0, 500.0),
        },
        4 => Request::Dc {
            session: sid(g),
            pop: ascii(g),
            dc_pa: g.f64(-500.0, 500.0),
        },
        5 => Request::Suspend { session: sid(g) },
        6 => Request::Resume { session: sid(g) },
        7 => Request::Checkpoint { session: sid(g) },
        8 => Request::Close { session: sid(g) },
        _ => {
            if g.bool(0.5) {
                Request::Stats
            } else {
                Request::Shutdown
            }
        }
    }
}

fn random_serve_reply(g: &mut Gen) -> Reply {
    match g.u32(0..9) {
        0 => Reply::Ok,
        1 => Reply::Created { session: sid(g) },
        2 => Reply::Refused(match g.u32(0..4) {
            0 => AdmissionError::Sessions {
                active: sid(g),
                max: sid(g),
            },
            1 => AdmissionError::Threads {
                want: sid(g),
                in_use: sid(g),
                budget: sid(g),
            },
            2 => AdmissionError::Memory {
                want_bytes: sid(g),
                in_use: sid(g),
                budget: sid(g),
            },
            _ => AdmissionError::SessionThreads {
                want: sid(g),
                max: sid(g),
            },
        }),
        3 => Reply::Error(ascii(g)),
        4 => Reply::Ran { session: sid(g), step: sid(g) },
        5 => Reply::Data {
            probe: ascii(g),
            data: random_probe_data(g),
        },
        6 => Reply::Push {
            session: sid(g),
            probe: ascii(g),
            data: random_probe_data(g),
        },
        7 => Reply::Blob(
            (0..g.usize(0..64))
                .map(|_| g.u32(0..256) as u8)
                .collect(),
        ),
        _ => Reply::Stats(ServeStats {
            sessions: sid(g),
            active: sid(g),
            suspended: sid(g),
            threads_in_use: sid(g),
            thread_budget: sid(g),
            mem_in_use: sid(g),
            mem_budget: sid(g),
        }),
    }
}

#[test]
fn serve_frames_roundtrip_exactly() {
    property("serve request/reply roundtrip", 300, |g| {
        let req = random_serve_request(g);
        let bytes = proto::encode_request(&req);
        let back = proto::decode_request(&bytes)
            .map_err(|e| format!("request decode failed: {e}"))?;
        if back != req {
            return Err(format!("request mismatch: {req:?}"));
        }
        let rep = random_serve_reply(g);
        let bytes = proto::encode_reply(&rep);
        let back = proto::decode_reply(&bytes)
            .map_err(|e| format!("reply decode failed: {e}"))?;
        if back != rep {
            return Err(format!("reply mismatch: {rep:?}"));
        }
        // and through the length-prefixed framing layer
        let mut wire = Vec::new();
        proto::write_frame(&mut wire, &bytes)
            .map_err(|e| format!("write_frame failed: {e:#}"))?;
        let frame = proto::read_frame(&mut Cursor::new(wire))
            .map_err(|e| format!("read_frame failed: {e:#}"))?;
        if frame != bytes {
            return Err("framing changed the payload".into());
        }
        Ok(())
    });
}

#[test]
fn serve_garbage_never_panics_only_typed_errors() {
    property("serve garbage decode is total", 500, |g| {
        let n = g.usize(0..200);
        let bytes: Vec<u8> =
            (0..n).map(|_| g.u32(0..256) as u8).collect();
        // any returned value is fine — Ok or ProtoError, never a panic
        let _ = proto::decode_request(&bytes);
        let _ = proto::decode_reply(&bytes);
        let _ = proto::read_frame_opt(&mut Cursor::new(&bytes));
        Ok(())
    });
}

#[test]
fn every_truncation_of_a_serve_frame_errors() {
    property("serve truncations error out", 100, |g| {
        let req = random_serve_request(g);
        let bytes = proto::encode_request(&req);
        for cut in 0..bytes.len() {
            if proto::decode_request(&bytes[..cut]).is_ok() {
                return Err(format!(
                    "request prefix {cut}/{} decoded",
                    bytes.len()
                ));
            }
        }
        let rep = random_serve_reply(g);
        let bytes = proto::encode_reply(&rep);
        for cut in 0..bytes.len() {
            if proto::decode_reply(&bytes[..cut]).is_ok() {
                return Err(format!(
                    "reply prefix {cut}/{} decoded",
                    bytes.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn serve_bit_flips_never_panic() {
    property("serve bit flips are total", 300, |g| {
        let bytes = if g.bool(0.5) {
            proto::encode_request(&random_serve_request(g))
        } else {
            proto::encode_reply(&random_serve_reply(g))
        };
        let mut bytes = bytes;
        let byte = g.usize(0..bytes.len());
        let bit = g.u32(0..8);
        bytes[byte] ^= 1 << bit;
        // a flipped frame may decode to something else or error — it
        // must only never panic
        let _ = proto::decode_request(&bytes);
        let _ = proto::decode_reply(&bytes);
        Ok(())
    });
}

#[test]
fn oversized_serve_frame_prefix_is_a_typed_error() {
    // a hostile length prefix must be refused before any allocation
    let mut wire = Vec::from(u32::MAX.to_le_bytes());
    wire.extend_from_slice(&[0u8; 16]);
    let err =
        proto::read_frame(&mut Cursor::new(wire)).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ProtoError>(),
            Some(ProtoError::FrameTooLarge { .. })
        ),
        "expected FrameTooLarge, got: {err:#}"
    );
}

#[test]
fn serve_hello_mismatches_are_typed_errors() {
    let mut good = Vec::new();
    proto::send_hello(&mut good).unwrap();
    proto::expect_hello(&mut Cursor::new(good.clone())).unwrap();

    let mut bad_magic = good.clone();
    bad_magic[0] ^= 0xff;
    let err = proto::expect_hello(&mut Cursor::new(bad_magic))
        .unwrap_err();
    assert!(matches!(
        err.downcast_ref::<ProtoError>(),
        Some(ProtoError::BadMagic { .. })
    ));

    let mut bad_version = good;
    bad_version[8] ^= 0xff;
    let err = proto::expect_hello(&mut Cursor::new(bad_version))
        .unwrap_err();
    assert!(matches!(
        err.downcast_ref::<ProtoError>(),
        Some(ProtoError::BadVersion { .. })
    ));
}
