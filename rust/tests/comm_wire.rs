//! The spike wire codec as a trust boundary, plus the TCP rank runtime
//! end to end.
//!
//! Adversarial property tests (via `util::proptest_lite`): random spike
//! windows round-trip bit-exactly through `bsb::pack`/`unpack` and the
//! framed `encode_frame`/`decode_frame`, while random, truncated and
//! bit-flipped byte strings only ever produce `CodecError`s — never
//! panics. Then the acceptance criterion of the distributed runtime:
//! a 2-rank Potjans run over `TcpComm` on localhost produces a spike
//! raster **bit-identical** to the same spec/seed/threads run over
//! `LocalComm`, in both `serialized` and `overlap` comm modes.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use cortex::atlas::potjans::potjans_spec;
use cortex::comm::bsb::{self, CodecError};
use cortex::comm::{Communicator, SpikeMsg, TcpComm};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind,
};
use cortex::engine::{run_simulation, RunConfig, Simulation};
use cortex::util::proptest_lite::{property, Gen};

fn random_window(g: &mut Gen) -> (u32, Vec<SpikeMsg>) {
    let start = g.u32(0..1_000_000);
    let len = g.u32(1..30);
    let n = g.usize(0..200);
    let spikes = (0..n)
        .map(|_| SpikeMsg {
            gid: g.u32(0..200_000),
            step: start + g.u32(0..len),
        })
        .collect();
    (start, spikes)
}

#[test]
fn random_windows_roundtrip_exactly() {
    property("pack/unpack roundtrip", 200, |g| {
        let (start, spikes) = random_window(g);
        let buf = bsb::pack(start, &spikes)
            .map_err(|e| format!("pack failed: {e}"))?;
        let got = bsb::unpack(start, &buf)
            .map_err(|e| format!("unpack failed: {e}"))?;
        let mut want = spikes.clone();
        want.sort_unstable_by_key(|m| (m.step, m.gid));
        if got != want {
            return Err(format!(
                "mismatch: {} in, {} out",
                want.len(),
                got.len()
            ));
        }
        // the framed form carries the window counter alongside
        let window = g.usize(0..1_000_000) as u64;
        let frame = bsb::encode_frame(window, &spikes)
            .map_err(|e| format!("encode_frame failed: {e}"))?;
        let (w, got) = bsb::decode_frame(&frame)
            .map_err(|e| format!("decode_frame failed: {e}"))?;
        let mut got = got;
        got.sort_unstable_by_key(|m| (m.step, m.gid));
        if w != window || got != want {
            return Err("frame roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn random_bytes_never_panic_only_error() {
    property("garbage decode is total", 500, |g| {
        let n = g.usize(0..200);
        let bytes: Vec<u8> =
            (0..n).map(|_| g.u32(0..256) as u8).collect();
        let start = g.u32(0..1_000_000);
        // any outcome is fine as long as it is a returned value
        let _ = bsb::unpack(start, &bytes);
        let _ = bsb::decode_frame(&bytes);
        Ok(())
    });
}

#[test]
fn every_truncation_of_a_valid_packet_errors() {
    property("truncations error out", 100, |g| {
        let (start, mut spikes) = random_window(g);
        if spikes.is_empty() {
            spikes.push(SpikeMsg { gid: 7, step: start });
        }
        let buf = bsb::pack(start, &spikes)
            .map_err(|e| format!("pack failed: {e}"))?;
        for cut in 0..buf.len() {
            if bsb::unpack(start, &buf[..cut]).is_ok() {
                return Err(format!(
                    "prefix of {cut}/{} bytes decoded successfully",
                    buf.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn bit_flips_never_panic() {
    property("bit flips are total", 200, |g| {
        let (start, spikes) = random_window(g);
        let window = g.usize(0..1000) as u64;
        let mut frame = bsb::encode_frame(window, &spikes)
            .map_err(|e| format!("encode_frame failed: {e}"))?;
        let byte = g.usize(0..frame.len());
        let bit = g.u32(0..8);
        frame[byte] ^= 1 << bit;
        // a flipped frame may still decode (to different spikes) or
        // error — it must only never panic
        let _ = bsb::decode_frame(&frame);
        let _ = bsb::unpack(start, &frame);
        Ok(())
    });
}

#[test]
fn overlong_varint_is_rejected() {
    let buf = vec![0xffu8; 16];
    assert_eq!(bsb::unpack(0, &buf), Err(CodecError::VarintOverflow));
    assert!(bsb::decode_frame(&buf).is_err());
}

// ---------------------------------------------------------------------
// TCP rank runtime: bit-identity against the in-memory transport
// ---------------------------------------------------------------------

const SCALE: f64 = 1600.0 / 77_169.0;
const SEED: u64 = 23;
const STEPS: u64 = 600;
const THREADS: usize = 2;

fn local_raster(
    spec: &Arc<cortex::atlas::NetworkSpec>,
    comm: CommMode,
) -> Vec<(u64, u32)> {
    let out = run_simulation(
        spec,
        &RunConfig {
            ranks: 2,
            threads: THREADS,
            mapping: MappingKind::AreaProcesses,
            comm,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            steps: STEPS,
            record_limit: Some(u32::MAX),
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed: SEED,
        },
    )
    .unwrap();
    out.raster.events
}

/// Run the same 2-rank simulation as two single-rank TCP sessions (one
/// per thread, real sockets on ephemeral localhost ports), driving
/// each through the given `run_for` chunks, and merge their rasters.
fn tcp_raster(
    spec: &Arc<cortex::atlas::NetworkSpec>,
    comm: CommMode,
    chunks: &[u64],
) -> Vec<(u64, u32)> {
    let listeners: Vec<TcpListener> = (0..2)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    let peers: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(rank, listener)| {
            let spec = Arc::clone(spec);
            let peers = peers.clone();
            let chunks = chunks.to_vec();
            thread::spawn(move || {
                let endpoint = TcpComm::join_with_listener(
                    rank as u16,
                    listener,
                    &peers,
                    Duration::from_secs(30),
                )
                .unwrap();
                let mut sim = Simulation::builder(spec)
                    .ranks(2)
                    .threads(THREADS)
                    .mapping(MappingKind::AreaProcesses)
                    .comm(comm)
                    .record_limit(Some(u32::MAX))
                    .seed(SEED)
                    .transport_with(move |n| {
                        assert_eq!(n, 2);
                        Ok(vec![(
                            rank,
                            Box::new(endpoint)
                                as Box<dyn Communicator>,
                        )])
                    })
                    .build()
                    .unwrap();
                for steps in chunks {
                    sim.run_for(steps).unwrap();
                }
                let out = sim.finish().unwrap();
                out.raster.events
            })
        })
        .collect();
    let mut events = Vec::new();
    for h in handles {
        events.extend(h.join().unwrap());
    }
    events.sort_unstable();
    events
}

#[test]
fn tcp_two_rank_potjans_raster_bit_identical_to_local() {
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    for comm in [CommMode::Serialized, CommMode::Overlap] {
        let want = local_raster(&spec, comm);
        assert!(
            !want.is_empty(),
            "{comm:?}: microcircuit should be active"
        );
        let got = tcp_raster(&spec, comm, &[STEPS]);
        assert_eq!(
            got, want,
            "{comm:?}: TCP transport changed the raster \
             ({} vs {} events)",
            got.len(),
            want.len()
        );
    }
}

#[test]
fn tcp_split_runs_stay_aligned_across_windows() {
    // run_for in uneven chunks (including mid-window stops) over TCP:
    // the per-window frame counters must stay aligned and the merged
    // raster identical to one combined local run. 7 + 100 + 493 = 600.
    let spec = Arc::new(potjans_spec(SCALE, SEED));
    let want = local_raster(&spec, CommMode::Overlap);
    let got = tcp_raster(&spec, CommMode::Overlap, &[7, 100, 493]);
    assert_eq!(got, want, "split TCP runs diverged from local");
}
