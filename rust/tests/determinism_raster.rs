//! The paper's race-freedom claim, tested head-on (§III.B / §IV.A): the
//! mutex-free thread-ownership scheme may never let the thread count
//! change a result. Thread `t` owns its posts' edges, ring rows and
//! plastic state outright, so per-post delivery order — and therefore
//! every floating-point sum — is independent of how many workers the
//! rank runs. We assert byte-identical spike rasters on the Potjans
//! microcircuit for `threads ∈ {1, 2, 4}` under both exchange modes, and
//! byte-identical final STDP weights on the plastic hpc_benchmark.

use std::sync::Arc;

use cortex::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use cortex::atlas::potjans::{
    potjans_spec, potjans_spec_with, PotjansModels,
};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::decomp::{area_processes_partition, RankStore};
use cortex::engine::{
    run_simulation, EngineOptions, RankEngine, RunConfig,
};
use cortex::model::{AdexParams, LifParams, ModelParams};

#[test]
fn potjans_raster_identical_across_thread_counts_and_comm_modes() {
    // ~1600-neuron downscaled microcircuit, 60 ms
    let spec = Arc::new(potjans_spec(1600.0 / 77_169.0, 23));
    for comm in [CommMode::Serialized, CommMode::Overlap] {
        let mut reference = None;
        for threads in [1usize, 2, 4] {
            let out = run_simulation(
                &spec,
                &RunConfig {
                    ranks: 2,
                    threads,
                    mapping: MappingKind::AreaProcesses,
                    comm,
                    backend: DynamicsBackend::Native,
                    exec: ExecMode::Pool,
                    build: BuildMode::TwoPass,
                    integrate: IntegrateMode::Vector,
                    routing: RoutingMode::Routed,
                    steps: 600,
                    record_limit: Some(u32::MAX),
                    verify_ownership: true,
                    artifacts_dir: "artifacts".into(),
                    seed: 23,
                },
            )
            .unwrap();
            assert!(
                out.total_spikes > 0,
                "microcircuit should be active ({comm:?}, {threads}t)"
            );
            if let Some(want) = &reference {
                assert_eq!(
                    want, &out.raster.events,
                    "{comm:?}: {threads} threads changed the raster"
                );
            } else {
                reference = Some(out.raster.events);
            }
        }
    }
}

#[test]
fn build_pipelines_produce_identical_rasters() {
    // the two-pass streaming builder vs the serial staging ablation:
    // same spec, same partition — the realised network, and therefore
    // the full raster, must be bit-identical at every thread count
    let spec = Arc::new(potjans_spec(1200.0 / 77_169.0, 37));
    let mut reference = None;
    for build in [BuildMode::Serial, BuildMode::TwoPass] {
        for threads in [1usize, 2, 4] {
            let out = run_simulation(
                &spec,
                &RunConfig {
                    ranks: 2,
                    threads,
                    mapping: MappingKind::AreaProcesses,
                    comm: CommMode::Overlap,
                    backend: DynamicsBackend::Native,
                    exec: ExecMode::Pool,
                    build,
                    integrate: IntegrateMode::Vector,
                    routing: RoutingMode::Routed,
                    steps: 400,
                    record_limit: Some(u32::MAX),
                    verify_ownership: true,
                    artifacts_dir: "artifacts".into(),
                    seed: 37,
                },
            )
            .unwrap();
            assert!(out.total_spikes > 0, "{build:?} {threads}t inactive");
            if let Some(want) = &reference {
                assert_eq!(
                    want, &out.raster.events,
                    "{build:?} at {threads} threads changed the raster"
                );
            } else {
                reference = Some(out.raster.events);
            }
        }
    }
}

#[test]
fn integrate_kernels_produce_identical_rasters() {
    // the branch-free vector kernels vs the scalar ablation: spike
    // rasters must be bit-identical on the all-LIF microcircuit AND on
    // the mixed AdEx/LIF variant, at every thread count — the vector
    // formulation reorders no floating-point operation
    let lif = Arc::new(potjans_spec(1200.0 / 77_169.0, 41));
    let mixed = Arc::new(potjans_spec_with(
        1200.0 / 77_169.0,
        41,
        &PotjansModels {
            e: ModelParams::Adex(AdexParams {
                i_ext: 700.0,
                ..Default::default()
            }),
            i: ModelParams::Lif(LifParams::default()),
        },
    ));
    assert!(!mixed.all_lif(), "variant should actually be mixed");
    for spec in [&lif, &mixed] {
        let mut reference = None;
        for integrate in [IntegrateMode::Scalar, IntegrateMode::Vector] {
            for threads in [1usize, 2, 4] {
                let out = run_simulation(
                    spec,
                    &RunConfig {
                        ranks: 2,
                        threads,
                        mapping: MappingKind::AreaProcesses,
                        comm: CommMode::Overlap,
                        backend: DynamicsBackend::Native,
                        exec: ExecMode::Pool,
                        build: BuildMode::TwoPass,
                        integrate,
                        routing: RoutingMode::Routed,
                        steps: 400,
                        record_limit: Some(u32::MAX),
                        verify_ownership: true,
                        artifacts_dir: "artifacts".into(),
                        seed: 41,
                    },
                )
                .unwrap();
                assert!(
                    out.total_spikes > 0,
                    "'{}' inactive ({integrate:?}, {threads}t)",
                    spec.name
                );
                if let Some(want) = &reference {
                    assert_eq!(
                        want, &out.raster.events,
                        "{integrate:?} at {threads} threads changed \
                         the '{}' raster",
                        spec.name
                    );
                } else {
                    reference = Some(out.raster.events);
                }
            }
        }
    }
}

#[test]
fn integrate_kernels_agree_on_checkpoint_bytes() {
    // stronger than raster identity: the scalar and vector kernels must
    // agree on every state variable (u, w, currents, refractory clocks),
    // all of which the checkpoint byte stream captures
    let spec = Arc::new(potjans_spec_with(
        1600.0 / 77_169.0,
        31,
        &PotjansModels {
            e: ModelParams::Adex(AdexParams {
                i_ext: 700.0,
                ..Default::default()
            }),
            i: ModelParams::Lif(LifParams::default()),
        },
    ));
    let part = area_processes_partition(&spec, 1, 31);
    let run = |integrate: IntegrateMode| {
        let store = RankStore::build(
            &spec,
            &part.members[0],
            |_| true,
            0,
            2,
        );
        let mut eng = RankEngine::new(
            Arc::clone(&spec),
            store,
            EngineOptions {
                n_threads: 2,
                verify_ownership: true,
                integrate,
                ..Default::default()
            },
        )
        .unwrap();
        let spikes = eng.run_windows_solo(80);
        let mut blob = Vec::new();
        eng.checkpoint(&mut blob).unwrap();
        (spikes, blob)
    };
    let (spikes_s, blob_s) = run(IntegrateMode::Scalar);
    let (spikes_v, blob_v) = run(IntegrateMode::Vector);
    assert!(!spikes_s.is_empty(), "mixed circuit should be active");
    assert_eq!(
        spikes_s, spikes_v,
        "kernel formulation changed the spike train"
    );
    assert_eq!(
        blob_s, blob_v,
        "kernel formulation changed the checkpoint bytes"
    );
}

#[test]
fn stdp_weights_identical_across_thread_counts() {
    // plastic balanced random network, hot enough to move weights fast
    let spec = Arc::new(hpc_benchmark_spec(
        &HpcParams {
            n_neurons: 500,
            indegree: 100,
            plastic: true,
            eta: 0.95,
            ..Default::default()
        },
        29,
    ));
    let part = area_processes_partition(&spec, 1, 29);
    let run = |threads: usize| {
        let store = RankStore::build(
            &spec,
            &part.members[0],
            |_| true,
            0,
            threads,
        );
        let mut eng = RankEngine::new(
            Arc::clone(&spec),
            store,
            EngineOptions {
                n_threads: threads,
                verify_ownership: true,
                ..Default::default()
            },
        )
        .unwrap();
        // the default ExecMode::Pool must actually engage the persistent
        // pool whenever there is real parallelism (a silent fallback to
        // inline execution would make this test vacuous)
        assert_eq!(eng.n_workers(), threads);
        assert_eq!(eng.uses_pool(), threads > 1);
        let spikes = eng.run_windows_solo(60);
        (spikes, eng.plastic_edges())
    };
    let (spikes1, weights1) = run(1);
    assert!(!spikes1.is_empty(), "plastic network should be active");
    assert!(!weights1.is_empty(), "network should have plastic edges");
    for threads in [2usize, 4] {
        let (spikes, weights) = run(threads);
        assert_eq!(
            spikes1, spikes,
            "{threads} threads changed the spike train"
        );
        assert_eq!(
            weights1, weights,
            "{threads} threads changed the final STDP weights"
        );
    }
}

#[test]
fn mixed_model_potjans_deterministic_and_checkpointable() {
    // AdEx pyramidal layers over LIF interneurons. The constant i_ext on
    // the AdEx populations sits above rheobase, so the circuit is active
    // regardless of the Poisson drive's realisation.
    let spec = Arc::new(potjans_spec_with(
        1600.0 / 77_169.0,
        31,
        &PotjansModels {
            e: ModelParams::Adex(AdexParams {
                i_ext: 700.0,
                ..Default::default()
            }),
            i: ModelParams::Lif(LifParams::default()),
        },
    ));
    assert!(!spec.all_lif(), "variant should actually be mixed");
    let part = area_processes_partition(&spec, 1, 31);

    // run 80 windows, checkpoint, run 80 more; then restore the snapshot
    // into a FRESH engine and replay the second half
    let run = |threads: usize| {
        let mk = || {
            let store = RankStore::build(
                &spec,
                &part.members[0],
                |_| true,
                0,
                threads,
            );
            RankEngine::new(
                Arc::clone(&spec),
                store,
                EngineOptions {
                    n_threads: threads,
                    verify_ownership: true,
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let mut eng = mk();
        let first = eng.run_windows_solo(80);
        let mut blob = Vec::new();
        eng.checkpoint(&mut blob).unwrap();
        let second = eng.run_windows_solo(80);
        drop(eng);
        let mut resumed = mk();
        resumed.restore(&mut std::io::Cursor::new(&blob)).unwrap();
        let replayed = resumed.run_windows_solo(80);
        assert_eq!(
            second, replayed,
            "{threads}t: checkpoint resume diverged on the mixed circuit"
        );
        (first, second, blob)
    };

    let (first1, second1, blob1) = run(1);
    assert!(!first1.is_empty(), "mixed AdEx/LIF circuit inactive");
    for threads in [2usize, 4] {
        let (first, second, blob) = run(threads);
        assert_eq!(
            first1, first,
            "{threads} threads changed the mixed-model raster"
        );
        assert_eq!(second1, second);
        // model segments merge across worker boundaries, so even the
        // checkpoint byte stream is thread-count independent
        assert_eq!(
            blob1, blob,
            "{threads} threads changed the checkpoint bytes"
        );
    }
}
