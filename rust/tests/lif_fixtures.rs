//! Replay the python-generated LIF reference trajectories against the
//! native Rust model: the L1 Pallas kernel, the pure-jnp oracle, and the
//! Rust engine must implement the *same* exact-integration step.
//!
//! Fixtures are produced by `make artifacts`
//! (python/compile/kernels/ref.py → artifacts/fixtures/lif_fixtures.json).

use cortex::model::lif::{step_slice, LifParams, LifState, Propagators};
use cortex::util::json::Json;

fn load_fixtures() -> Option<Json> {
    let path = std::path::Path::new("artifacts/fixtures/lif_fixtures.json");
    if !path.exists() {
        eprintln!(
            "SKIP: {} not found — run `make artifacts` first",
            path.display()
        );
        return None;
    }
    Some(Json::parse(&std::fs::read_to_string(path).unwrap()).unwrap())
}

fn params_from(case: &Json) -> (LifParams, f64) {
    let c = case.get("config").unwrap();
    let g = |k: &str| c.get(k).unwrap().as_f64().unwrap();
    (
        LifParams {
            tau_m: g("tau_m"),
            tau_syn_ex: g("tau_syn_ex"),
            tau_syn_in: g("tau_syn_in"),
            c_m: g("c_m"),
            e_l: g("e_l"),
            v_reset: g("v_reset"),
            v_th: g("v_th"),
            t_ref: g("t_ref"),
            i_ext: g("i_ext"),
        },
        g("dt"),
    )
}

#[test]
fn propagators_match_python() {
    let Some(fx) = load_fixtures() else { return };
    for case in fx.get("cases").unwrap().as_arr().unwrap() {
        let (params, dt) = params_from(case);
        let props = Propagators::new(&params, dt);
        let p = case.get("propagators").unwrap();
        let g = |k: &str| p.get(k).unwrap().as_f64().unwrap();
        let name = case.get("name").unwrap().as_str().unwrap();
        for (got, want, label) in [
            (props.p22, g("p22"), "p22"),
            (props.p11e, g("p11e"), "p11e"),
            (props.p11i, g("p11i"), "p11i"),
            (props.p21e, g("p21e"), "p21e"),
            (props.p21i, g("p21i"), "p21i"),
            (props.p20, g("p20"), "p20"),
        ] {
            assert!(
                (got - want).abs() <= 1e-15 * want.abs().max(1.0),
                "case {name}: {label} {got} != {want}"
            );
        }
        assert_eq!(props.ref_steps as f64, g("ref_steps"), "case {name}");
    }
}

#[test]
fn trajectories_replay_exactly() {
    let Some(fx) = load_fixtures() else { return };
    for case in fx.get("cases").unwrap().as_arr().unwrap() {
        let name = case.get("name").unwrap().as_str().unwrap();
        let (params, dt) = params_from(case);
        let props = [Propagators::new(&params, dt)];
        let traj = case.get("trajectory").unwrap();
        let v = |k: &str| traj.get(k).unwrap().as_f64_vec().unwrap();
        let series = |k: &str| -> Vec<Vec<f64>> {
            traj.get(k)
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_f64_vec().unwrap())
                .collect()
        };

        let u0 = v("u0");
        let n = u0.len();
        let mut state = LifState::new(n, &props, vec![0; n]);
        state.u = u0;
        state.ie = v("ie0");
        state.ii = v("ii0");

        let in_e = series("in_e");
        let in_i = series("in_i");
        let want_u = series("u");
        let want_ie = series("ie");
        let want_r = series("refrac");
        let want_s = series("spiked");

        for t in 0..in_e.len() {
            let mut spikes = Vec::new();
            step_slice(
                &mut state, 0, n, &in_e[t], &in_i[t], &props, &mut spikes,
            );
            for i in 0..n {
                // python wrote f64 through JSON (shortest round-trip
                // repr), so equality is exact up to the JSON round-trip
                let close = |a: f64, b: f64| {
                    (a - b).abs() <= 1e-12 * b.abs().max(1.0)
                };
                assert!(
                    close(state.u[i], want_u[t][i]),
                    "case {name} step {t} neuron {i}: u {} != {}",
                    state.u[i],
                    want_u[t][i]
                );
                assert!(close(state.ie[i], want_ie[t][i]), "ie mismatch");
                assert!(
                    state.refrac[i] == want_r[t][i],
                    "case {name} step {t} neuron {i}: refrac"
                );
                let spiked = spikes.contains(&(i as u32));
                assert_eq!(
                    spiked,
                    want_s[t][i] != 0.0,
                    "case {name} step {t} neuron {i}: spike flag"
                );
            }
        }
    }
}
