//! CLI-level integration: config files → spec builders → short runs of
//! every workload kind, exercising the same paths the launcher uses.

use std::sync::Arc;

use cortex::cli::{build_spec, run_config_of, Args};
use cortex::config::{ConfigDoc, EngineKind, ExperimentConfig};
use cortex::engine::run_simulation;

fn args(sets: &[&str]) -> Args {
    let mut v = vec!["run".to_string()];
    for s in sets {
        v.push("--set".into());
        v.push(s.to_string());
    }
    Args::parse(&v).unwrap()
}

#[test]
fn potjans_microcircuit_short_run() {
    let a = args(&[
        "network.kind=\"potjans\"",
        "network.n_neurons=1600",
        "sim.sim_ms=100",
        "engine.ranks=2",
        "engine.threads=2",
    ]);
    let cfg = a.experiment().unwrap();
    let spec = Arc::new(build_spec(&cfg));
    assert_eq!(spec.populations.len(), 8);
    let out = run_simulation(&spec, &run_config_of(&cfg)).unwrap();
    assert!(
        out.total_spikes > 0,
        "downscaled microcircuit should be active"
    );
}

#[test]
fn marmoset_short_run_produces_ai_activity() {
    let a = args(&[
        "network.kind=\"marmoset\"",
        "network.n_neurons=2000",
        "network.n_areas=4",
        "network.indegree=100",
        "sim.sim_ms=100",
        "sim.record_raster=true",
        "sim.record_limit=2000",
        "engine.ranks=4",
    ]);
    let cfg = a.experiment().unwrap();
    let spec = Arc::new(build_spec(&cfg));
    let out = run_simulation(&spec, &run_config_of(&cfg)).unwrap();
    let rate =
        out.total_spikes as f64 / spec.n_total() as f64 / (cfg.sim_ms * 1e-3);
    assert!(
        rate > 0.5 && rate < 60.0,
        "marmoset rate {rate:.1} Hz not in a plausible cortical band"
    );
    // not every neuron should fire in a 100 ms AI-regime window
    let stats = out.raster.stats(spec.n_total(), cfg.dt_ms, cfg.steps());
    assert!(
        stats.active_fraction < 1.0,
        "suspiciously regular: every neuron fired"
    );
}

#[test]
fn config_file_round_trip() {
    let text = r#"
title = "integration"
[network]
kind = "random"
n_neurons = 300
indegree = 30
[sim]
sim_ms = 10
[engine]
kind = "nest_baseline"
ranks = 2
"#;
    let doc = ConfigDoc::parse(text).unwrap();
    let cfg = ExperimentConfig::from_doc(&doc).unwrap();
    assert_eq!(cfg.engine, EngineKind::NestBaseline);
    assert_eq!(cfg.steps(), 100);
    let spec = build_spec(&cfg);
    assert_eq!(spec.n_total(), 300);
}

#[test]
fn shipped_config_files_parse_and_validate() {
    for entry in std::fs::read_dir("configs").unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let doc = ConfigDoc::load(&path)
            .unwrap_or_else(|e| panic!("{path:?}: {e}"));
        let cfg = ExperimentConfig::from_doc(&doc)
            .unwrap_or_else(|e| panic!("{path:?}: {e}"));
        let spec = build_spec(&cfg);
        assert!(spec.n_total() > 0, "{path:?}");
    }
}
