//! Integration tests across decomp + engine + comm + nest_baseline.
//!
//! The deterministic network/noise streams (see `atlas`, `model::poisson`)
//! make strong cross-checks possible:
//! * same configuration twice          → bit-identical spike trains;
//! * overlap vs serialized exchange    → bit-identical spike trains;
//! * 1 thread vs 3 threads             → bit-identical spike trains
//!   (the mutex-free ownership scheme cannot change delivery order per
//!   post-neuron);
//! * CORTEX vs the NEST-style baseline → bit-identical spike trains at
//!   matching distribution (stronger than the paper's statistical Fig 19);
//! * different rank counts / mappings  → statistically equivalent activity.

use std::sync::Arc;

use cortex::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::atlas::random_spec;
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::nest_baseline::{run_nest_simulation, NestRunConfig};

fn base_cfg(steps: u64) -> RunConfig {
    RunConfig {
        ranks: 2,
        threads: 2,
        mapping: MappingKind::AreaProcesses,
        comm: CommMode::Overlap,
        backend: DynamicsBackend::Native,
        exec: ExecMode::Pool,
        build: BuildMode::TwoPass,
        integrate: IntegrateMode::Vector,
        routing: RoutingMode::Routed,
        steps,
        record_limit: Some(u32::MAX),
        verify_ownership: true,
        artifacts_dir: "artifacts".into(),
        seed: 99,
    }
}

#[test]
fn deterministic_repeat() {
    let spec = Arc::new(random_spec(400, 40, 7));
    let cfg = base_cfg(300);
    let a = run_simulation(&spec, &cfg).unwrap();
    let b = run_simulation(&spec, &cfg).unwrap();
    assert!(a.total_spikes > 0, "network should be active");
    assert_eq!(a.raster.events, b.raster.events);
}

#[test]
fn overlap_equals_serialized() {
    let spec = Arc::new(random_spec(400, 40, 8));
    let mut cfg = base_cfg(300);
    let a = run_simulation(&spec, &cfg).unwrap();
    cfg.comm = CommMode::Serialized;
    let b = run_simulation(&spec, &cfg).unwrap();
    assert!(a.total_spikes > 0);
    assert_eq!(
        a.raster.events, b.raster.events,
        "overlap must not change results"
    );
}

#[test]
fn thread_count_does_not_change_results() {
    let spec = Arc::new(random_spec(400, 40, 9));
    let mut cfg = base_cfg(300);
    cfg.threads = 1;
    let a = run_simulation(&spec, &cfg).unwrap();
    cfg.threads = 3;
    let b = run_simulation(&spec, &cfg).unwrap();
    assert!(a.total_spikes > 0);
    assert_eq!(
        a.raster.events, b.raster.events,
        "thread partitioning must be result-invariant"
    );
}

#[test]
fn pool_equals_scoped_execution() {
    // the persistent worker pool and the per-step scoped-thread fallback
    // run the same phase kernels over the same owned state; swapping the
    // execution backend must not move a single spike
    let spec = Arc::new(random_spec(400, 40, 9));
    let mut cfg = base_cfg(300);
    cfg.threads = 3;
    let a = run_simulation(&spec, &cfg).unwrap();
    cfg.exec = ExecMode::Scoped;
    let b = run_simulation(&spec, &cfg).unwrap();
    assert!(a.total_spikes > 0);
    assert_eq!(
        a.raster.events, b.raster.events,
        "execution backend must be result-invariant"
    );
    // the pool reports its coordination overhead under `sync`
    assert!(a.timer_max.nanos("sync") > 0);
    assert!(b.timer_max.nanos("sync") > 0);
}

#[test]
fn cortex_matches_nest_baseline_spike_exact() {
    // single rank, single thread: identical delivery order ⇒ identical
    // floating-point sums ⇒ identical spike trains
    let spec = Arc::new(random_spec(300, 30, 10));
    let mut cfg = base_cfg(400);
    cfg.ranks = 1;
    cfg.threads = 1;
    let a = run_simulation(&spec, &cfg).unwrap();
    let b = run_nest_simulation(
        &spec,
        &NestRunConfig {
            ranks: 1,
            threads: 1,
            steps: 400,
            record_limit: Some(u32::MAX),
            seed: 99,
        },
    );
    assert!(a.total_spikes > 0);
    assert_eq!(a.total_spikes, b.total_spikes);
    assert_eq!(a.raster.events, b.raster.events);
}

#[test]
fn rank_count_statistically_equivalent() {
    let spec = Arc::new(random_spec(600, 60, 11));
    let mut cfg = base_cfg(500);
    cfg.ranks = 1;
    cfg.threads = 1;
    let a = run_simulation(&spec, &cfg).unwrap();
    cfg.ranks = 4;
    cfg.threads = 2;
    let b = run_simulation(&spec, &cfg).unwrap();
    // chaotic dynamics: spike-exact equality is not expected across
    // decompositions, but population activity must match closely
    let ra = a.total_spikes as f64;
    let rb = b.total_spikes as f64;
    assert!(ra > 0.0 && rb > 0.0);
    assert!(
        (ra - rb).abs() / ra.max(rb) < 0.2,
        "rates diverged: {ra} vs {rb}"
    );
}

#[test]
fn mapping_strategies_statistically_equivalent() {
    let spec = Arc::new(marmoset_spec(
        &MarmosetParams {
            n_neurons: 1200,
            n_areas: 4,
            indegree: 60,
            ..Default::default()
        },
        12,
    ));
    let mut cfg = base_cfg(400);
    cfg.ranks = 4;
    let a = run_simulation(&spec, &cfg).unwrap();
    cfg.mapping = MappingKind::RandomEquivalent;
    let b = run_simulation(&spec, &cfg).unwrap();
    let (ra, rb) = (a.total_spikes as f64, b.total_spikes as f64);
    assert!(ra > 0.0 && rb > 0.0, "marmoset net inactive: {ra} {rb}");
    assert!(
        (ra - rb).abs() / ra.max(rb) < 0.2,
        "mapping changed activity: {ra} vs {rb}"
    );
}

#[test]
fn stdp_changes_dynamics() {
    let mk = |plastic| {
        Arc::new(hpc_benchmark_spec(
            &HpcParams {
                n_neurons: 500,
                indegree: 100,
                plastic,
                ..Default::default()
            },
            13,
        ))
    };
    let mut cfg = base_cfg(2000); // 200 ms: enough for weights to move
    cfg.ranks = 2;
    let with = run_simulation(&mk(true), &cfg).unwrap();
    let without = run_simulation(&mk(false), &cfg).unwrap();
    assert!(with.total_spikes > 0);
    assert!(without.total_spikes > 0);
    assert_ne!(
        with.raster.events, without.raster.events,
        "plasticity should alter the spike train"
    );
}

#[test]
fn verification_case_rate_below_10hz() {
    // the paper's §IV.A acceptance: asynchronous regime, < 10 Hz
    let spec = Arc::new(hpc_benchmark_spec(
        &HpcParams {
            n_neurons: 1000,
            indegree: 100,
            plastic: true,
            ..Default::default()
        },
        14,
    ));
    let mut cfg = base_cfg(3000); // 300 ms
    cfg.ranks = 2;
    cfg.threads = 2;
    let out = run_simulation(&spec, &cfg).unwrap();
    let rate =
        out.total_spikes as f64 / spec.n_total() as f64 / 0.3;
    assert!(
        rate > 0.05 && rate < 10.0,
        "rate {rate:.2} Hz outside the verification band"
    );
}

#[test]
fn memory_accounting_cortex_below_baseline() {
    // Fig 18 memory panel shape: at equal problem size and ranks, the
    // baseline's O(N)-per-rank bookkeeping dominates CORTEX's store
    let spec = Arc::new(marmoset_spec(
        &MarmosetParams {
            n_neurons: 4000,
            n_areas: 8,
            indegree: 50,
            ..Default::default()
        },
        15,
    ));
    let mut cfg = base_cfg(10);
    cfg.ranks = 16; // > n_areas so the apportionment can balance areas
    cfg.threads = 1;
    let a = run_simulation(&spec, &cfg).unwrap();
    let b = run_nest_simulation(
        &spec,
        &NestRunConfig {
            ranks: 16,
            threads: 1,
            steps: 10,
            record_limit: None,
            seed: 99,
        },
    );
    assert!(
        a.memory.max_rank_bytes() < b.memory.max_rank_bytes(),
        "CORTEX {} >= baseline {}",
        a.memory.max_rank_bytes(),
        b.memory.max_rank_bytes()
    );
}

#[test]
fn windows_match_min_delay_batching() {
    let spec = Arc::new(random_spec(200, 20, 16));
    let mut cfg = base_cfg(600); // long enough for activity to start
    cfg.ranks = 2;
    let out = run_simulation(&spec, &cfg).unwrap();
    let m = spec.min_delay_steps as u64;
    assert_eq!(out.windows, 600u64.div_ceil(m));
    assert!(out.total_spikes > 0);
    assert!(out.comm_bytes > 0);
}

#[test]
fn checkpoint_resume_is_exact() {
    use cortex::decomp::{area_processes_partition, RankStore};
    use cortex::engine::{EngineOptions, RankEngine};
    use cortex::atlas::hpc::{hpc_benchmark_spec, HpcParams};

    // plastic network: the checkpoint must carry weights + traces too
    let spec = Arc::new(hpc_benchmark_spec(
        &HpcParams {
            n_neurons: 600,
            indegree: 120,
            eta: 0.95, // hotter than the verification point: the test
            // needs activity quickly, not the <10 Hz regime
            ..Default::default()
        },
        17,
    ));
    let part = area_processes_partition(&spec, 1, 17);
    let mk = || {
        let store = RankStore::build(&spec, &part.members[0], |_| true, 0, 2);
        RankEngine::new(
            Arc::clone(&spec),
            store,
            EngineOptions { n_threads: 2, ..Default::default() },
        )
        .unwrap()
    };

    // continuous run: 40 + 40 windows
    let mut cont = mk();
    let mut all = cont.run_windows_solo(40);
    all.extend(cont.run_windows_solo(40));

    // checkpointed run: 40 windows, snapshot, restore into a FRESH
    // engine, 40 more
    let mut a = mk();
    let first = a.run_windows_solo(40);
    let mut blob = Vec::new();
    a.checkpoint(&mut blob).unwrap();
    drop(a);
    let mut b = mk();
    b.restore(&mut std::io::Cursor::new(&blob)).unwrap();
    let second = b.run_windows_solo(40);

    let mut resumed = first;
    resumed.extend(second);
    assert!(!all.is_empty(), "network should be active");
    assert_eq!(all, resumed, "resume must be bit-exact");
}

#[test]
fn checkpoint_rejects_mismatched_shapes() {
    use cortex::decomp::{area_processes_partition, RankStore};
    use cortex::engine::{EngineOptions, RankEngine};

    let spec = Arc::new(random_spec(200, 20, 18));
    let part = area_processes_partition(&spec, 1, 18);
    let store = RankStore::build(&spec, &part.members[0], |_| true, 0, 1);
    let mut eng = RankEngine::new(
        Arc::clone(&spec),
        store,
        EngineOptions::default(),
    )
    .unwrap();
    let mut blob = Vec::new();
    eng.checkpoint(&mut blob).unwrap();

    // garbage magic
    assert!(eng
        .restore(&mut std::io::Cursor::new(&[0u8; 64][..]))
        .is_err());

    // different network shape
    let spec2 = Arc::new(random_spec(300, 20, 18));
    let part2 = area_processes_partition(&spec2, 1, 18);
    let store2 = RankStore::build(&spec2, &part2.members[0], |_| true, 0, 1);
    let mut eng2 = RankEngine::new(
        Arc::clone(&spec2),
        store2,
        EngineOptions::default(),
    )
    .unwrap();
    assert!(eng2.restore(&mut std::io::Cursor::new(&blob)).is_err());
}
