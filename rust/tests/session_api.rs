//! Session-API determinism (the acceptance bar of the API redesign):
//!
//! * `run_for(a); run_for(b)` is **bit-identical** to `run_for(a+b)` —
//!   even when `a` stops mid-window — across thread counts 1/2/4 and
//!   both exchange modes (the rank threads keep their window position
//!   across calls);
//! * probe outputs (raster, per-population rates, voltage traces, STDP
//!   weights) are bit-identical across thread counts;
//! * a session checkpointed mid-run — including after mid-run stimulus
//!   mutation — restores into a fresh session that replays the tail
//!   bit-exactly, at any thread count;
//! * `run_simulation` (now a thin wrapper over the session) still
//!   produces the same rasters as driving the session by hand.

use std::sync::Arc;

use cortex::atlas::hpc::{hpc_benchmark_spec, HpcParams};
use cortex::atlas::potjans::potjans_spec;
use cortex::atlas::random_spec;
use cortex::config::CommMode;
use cortex::engine::{run_simulation, RunConfig, Simulation};
use cortex::probe::{
    PopRates, ProbeData, SpikeRaster, VoltageTrace, WeightSnapshots,
};

fn base_cfg(steps: u64, threads: usize, comm: CommMode) -> RunConfig {
    RunConfig {
        ranks: 2,
        threads,
        comm,
        steps,
        record_limit: Some(u32::MAX),
        verify_ownership: true,
        seed: 11,
        ..Default::default()
    }
}

#[test]
fn split_run_for_matches_one_shot_across_threads_and_comm_modes() {
    let spec = Arc::new(random_spec(400, 40, 11));
    for comm in [CommMode::Overlap, CommMode::Serialized] {
        let reference =
            run_simulation(&spec, &base_cfg(600, 2, comm)).unwrap();
        assert!(reference.total_spikes > 0, "network should be active");
        for threads in [1usize, 2, 4] {
            let mut sim = Simulation::builder(Arc::clone(&spec))
                .run_config(&base_cfg(600, threads, comm))
                .probe(SpikeRaster::all("raster"))
                .build()
                .unwrap();
            // split mid-window on purpose (min_delay = 2 steps): the
            // second call must continue the partial window
            sim.run_for(251).unwrap();
            let mut probed = sim
                .drain("raster")
                .unwrap()
                .into_raster()
                .unwrap();
            sim.run_for(349).unwrap();
            probed.extend(
                sim.drain("raster").unwrap().into_raster().unwrap(),
            );
            let out = sim.finish().unwrap();
            assert_eq!(
                reference.raster.events, out.raster.events,
                "{comm:?}/{threads}t: split run_for changed the raster"
            );
            assert_eq!(
                reference.raster.events, probed,
                "{comm:?}/{threads}t: raster probe diverged from the \
                 built-in recorder"
            );
        }
    }
}

#[test]
fn split_run_preserves_stdp_weights_across_threads() {
    let spec = Arc::new(hpc_benchmark_spec(
        &HpcParams {
            n_neurons: 500,
            indegree: 100,
            plastic: true,
            eta: 0.95,
            ..Default::default()
        },
        29,
    ));
    let run = |threads: usize, splits: &[u64]| {
        let mut sim = Simulation::builder(Arc::clone(&spec))
            .ranks(1)
            .threads(threads)
            .verify_ownership(true)
            .probe(WeightSnapshots::new("w"))
            .probe(SpikeRaster::all("raster"))
            .build()
            .unwrap();
        for &s in splits {
            sim.run_for(s).unwrap();
        }
        let weights =
            sim.drain("w").unwrap().into_weights().unwrap();
        let raster =
            sim.drain("raster").unwrap().into_raster().unwrap();
        let (step, final_weights) = weights.into_iter().last().unwrap();
        assert_eq!(step, splits.iter().sum::<u64>());
        (raster, final_weights)
    };
    let (r1, w1) = run(1, &[120]);
    assert!(!r1.is_empty(), "plastic network should be active");
    assert!(!w1.is_empty(), "network should have plastic edges");
    for threads in [2usize, 4] {
        let (r, w) = run(threads, &[120]);
        assert_eq!(r1, r, "{threads}t changed the spike train");
        assert_eq!(w1, w, "{threads}t changed the final STDP weights");
    }
    // odd split points exercise mid-window continuation
    let (rs, ws) = run(2, &[37, 83]);
    assert_eq!(r1, rs, "split run_for changed the raster");
    assert_eq!(w1, ws, "split run_for changed the final STDP weights");
}

#[test]
fn probe_outputs_deterministic_across_thread_counts() {
    // ~1600-neuron downscaled microcircuit, 30 ms
    let spec = Arc::new(potjans_spec(1600.0 / 77_169.0, 23));
    let run = |threads: usize| {
        let mut sim = Simulation::builder(Arc::clone(&spec))
            .ranks(2)
            .threads(threads)
            .verify_ownership(true)
            .probe(SpikeRaster::pops("l23", &["L23E", "L23I"]))
            .probe(PopRates::new("rates", 100))
            .probe(VoltageTrace::new("vm", &[0, 5, 10], 10))
            .build()
            .unwrap();
        sim.run_for(300).unwrap();
        (
            sim.drain("l23").unwrap(),
            sim.drain("rates").unwrap(),
            sim.drain("vm").unwrap(),
        )
    };
    let (l23_1, rates1, vm1) = run(1);
    let ProbeData::Rates { rows, pops, .. } = &rates1 else {
        panic!("rates probe returned the wrong variant")
    };
    assert_eq!(rows.len(), 3, "300 steps at bin 100 = 3 rows");
    assert_eq!(pops.len(), spec.populations.len());
    let ProbeData::Traces(traces) = &vm1 else {
        panic!("voltage probe returned the wrong variant")
    };
    assert_eq!(traces.len(), 3);
    assert!(traces.iter().all(|(_, s)| s.len() == 30));
    for threads in [2usize, 4] {
        let (l23, rates, vm) = run(threads);
        assert_eq!(l23_1, l23, "{threads}t changed the L2/3 raster");
        assert_eq!(rates1, rates, "{threads}t changed the rates");
        assert_eq!(vm1, vm, "{threads}t changed the voltage traces");
    }
}

#[test]
fn checkpoint_restore_mid_session_is_bit_identical() {
    let spec = Arc::new(random_spec(400, 40, 13));
    // session A: run, steer the stimulus, checkpoint at a window
    // boundary, keep going
    let mut a = Simulation::builder(Arc::clone(&spec))
        .ranks(2)
        .threads(2)
        .record_limit(Some(u32::MAX))
        .verify_ownership(true)
        .build()
        .unwrap();
    a.run_for(200).unwrap();
    a.set_dc("E", 150.0).unwrap();
    a.set_poisson("I", 9_000.0, 87.8).unwrap();
    a.run_for(100).unwrap();
    // queued but not yet applied at checkpoint time: the snapshot must
    // carry it (it takes effect at this very boundary either way)
    a.set_poisson("E", 10_000.0, 87.8).unwrap();
    let mut blob = Vec::new();
    a.checkpoint(&mut blob).unwrap();
    a.run_for(300).unwrap();
    let out_a = a.finish().unwrap();
    assert!(out_a.total_spikes > 0);
    let tail_a: Vec<(u64, u32)> = out_a
        .raster
        .events
        .iter()
        .copied()
        .filter(|&(t, _)| t >= 300)
        .collect();
    assert!(!tail_a.is_empty(), "tail should be active");

    // restored sessions replay the tail bit-exactly — the checkpoint
    // bytes are thread-count independent, so restore at 4 threads too
    for threads in [2usize, 4] {
        let mut b = Simulation::builder(Arc::clone(&spec))
            .ranks(2)
            .threads(threads)
            .record_limit(Some(u32::MAX))
            .verify_ownership(true)
            .restore(&mut std::io::Cursor::new(&blob))
            .unwrap();
        assert_eq!(b.step(), 300);
        b.run_for(300).unwrap();
        let out_b = b.finish().unwrap();
        assert_eq!(
            tail_a, out_b.raster.events,
            "{threads}t: restored session diverged from the original"
        );
    }
}

#[test]
fn checkpoint_requires_window_boundary() {
    let spec = Arc::new(random_spec(200, 20, 5));
    let mut sim = Simulation::builder(Arc::clone(&spec))
        .ranks(1)
        .threads(1)
        .build()
        .unwrap();
    sim.run_for(3).unwrap(); // min_delay = 2 → mid-window
    let mut blob = Vec::new();
    assert!(sim.checkpoint(&mut blob).is_err());
    sim.run_for(1).unwrap();
    sim.checkpoint(&mut blob).unwrap();
    assert!(!blob.is_empty());
}

#[test]
fn stimulus_mutation_changes_activity_and_stays_deterministic() {
    let spec = Arc::new(random_spec(400, 40, 17));
    let run = |threads: usize| {
        let mut sim = Simulation::builder(Arc::clone(&spec))
            .ranks(2)
            .threads(threads)
            .verify_ownership(true)
            .probe(PopRates::new("rates", 200))
            .build()
            .unwrap();
        sim.run_for(200).unwrap();
        sim.set_poisson("E", 16_000.0, 87.8).unwrap(); // double it
        sim.run_for(200).unwrap();
        sim.set_poisson("E", 0.0, 0.0).unwrap(); // and switch it off
        sim.run_for(200).unwrap();
        let ProbeData::Rates { rows, pops, .. } =
            sim.drain("rates").unwrap()
        else {
            panic!("rates probe returned the wrong variant")
        };
        (pops, rows)
    };
    let (pops, rows) = run(2);
    let e = pops.iter().position(|n| n == "E").unwrap();
    assert_eq!(rows.len(), 3);
    assert!(
        rows[1].1[e] > rows[0].1[e],
        "doubling the E drive should raise the E rate \
         ({} vs {})",
        rows[1].1[e],
        rows[0].1[e]
    );
    assert!(
        rows[2].1[e] < rows[1].1[e],
        "removing the E drive should lower the E rate"
    );
    // the full (commands × windows) schedule is thread-count invariant
    let (_, rows4) = run(4);
    assert_eq!(rows, rows4);
}

#[test]
fn bad_targets_are_rejected() {
    let spec = Arc::new(random_spec(200, 20, 3));
    let mut sim = Simulation::builder(Arc::clone(&spec))
        .ranks(1)
        .threads(1)
        .build()
        .unwrap();
    assert!(sim.set_poisson("NOPE", 1000.0, 10.0).is_err());
    assert!(sim.set_dc("NOPE", 5.0).is_err());
    assert!(sim.drain("unregistered").is_err());
    // the session keeps working after a rejected command
    sim.run_for(10).unwrap();
    sim.finish().unwrap();

    // a typo'd probe filter fails at build(), not on a rank mid-run
    let err = Simulation::builder(Arc::clone(&spec))
        .probe(SpikeRaster::pops("bad", &["NOPE"]))
        .build();
    assert!(err.is_err());
}
