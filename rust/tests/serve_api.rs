//! End-to-end tests for the `cortex serve` daemon: a real TCP daemon
//! on an ephemeral port, driven through the typed [`Client`].
//!
//! The two acceptance properties of the serve subsystem:
//! * a session that is suspended and transparently resumed produces a
//!   spike raster **and** checkpoint bytes bit-identical to an
//!   uninterrupted in-process run of the same configuration;
//! * sessions over the `[serve]` thread/session quotas are refused
//!   with a typed [`AdmissionError`], downcastable client-side.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;

use anyhow::Result;

use cortex::cli::{build_spec, run_config_of};
use cortex::config::{ConfigDoc, ExperimentConfig, ServeConfig};
use cortex::engine::Simulation;
use cortex::probe::SpikeRaster;
use cortex::serve::{self, AdmissionError, Client, ProbeSpec};

/// The acceptance workload: the downscaled Potjans microcircuit, as
/// shipped in `configs/` (2 ranks × 2 threads, local transport).
const POTJANS: &str = include_str!("../../configs/potjans.toml");

fn start_daemon(
    limits: ServeConfig,
) -> (String, thread::JoinHandle<Result<()>>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let handle =
        thread::spawn(move || serve::serve_on(listener, limits));
    (addr, handle)
}

#[test]
fn suspended_session_is_bit_identical_to_uninterrupted_run() {
    let (addr, daemon) = start_daemon(ServeConfig::default());
    let mut client = Client::connect(&addr).unwrap();

    // daemon side: 300 steps, park to a blob, then 300 more — the
    // second run transparently rebuilds the session from the blob
    let probes = [ProbeSpec::Raster { name: "spikes".into() }];
    let sid = client.create(POTJANS, &[], &probes).unwrap();
    let (step, _) = client.run(sid, 300, false).unwrap();
    assert_eq!(step, 300);
    client.suspend(sid).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.suspended, 1);
    assert_eq!(stats.active, 0);
    assert_eq!(stats.threads_in_use, 0, "parked sessions cost no threads");
    let (step, _) = client.run(sid, 300, false).unwrap();
    assert_eq!(step, 600);
    let served = client
        .drain(sid, "spikes")
        .unwrap()
        .into_raster()
        .unwrap();
    let served_ckpt = client.checkpoint(sid).unwrap();
    client.close(sid).unwrap();
    client.shutdown().unwrap();
    daemon.join().unwrap().unwrap();

    // reference: the identical configuration run in-process without
    // interruption
    let doc = ConfigDoc::parse(POTJANS).unwrap();
    let cfg = ExperimentConfig::from_doc(&doc).unwrap();
    let spec = Arc::new(build_spec(&cfg));
    let rc = run_config_of(&cfg);
    let mut sim = Simulation::builder(spec)
        .run_config(&rc)
        .probe(SpikeRaster::all("spikes"))
        .build()
        .unwrap();
    sim.run_for(600).unwrap();
    let reference =
        sim.drain("spikes").unwrap().into_raster().unwrap();
    let mut reference_ckpt = Vec::new();
    sim.checkpoint(&mut reference_ckpt).unwrap();

    assert!(!reference.is_empty(), "workload should spike");
    assert_eq!(served, reference, "raster must survive suspend/resume");
    assert_eq!(
        served_ckpt, reference_ckpt,
        "checkpoint bytes must survive suspend/resume"
    );
}

/// A 1-rank × `threads`-thread random network, entirely from
/// overrides (no document).
fn tiny_overrides(threads: usize) -> Vec<String> {
    [
        "network.kind=\"random\"".to_string(),
        "network.n_neurons=200".to_string(),
        "network.indegree=20".to_string(),
        "seed=7".to_string(),
        "engine.ranks=1".to_string(),
        format!("engine.threads={threads}"),
    ]
    .to_vec()
}

fn admission_of(e: &anyhow::Error) -> &AdmissionError {
    e.downcast_ref::<AdmissionError>()
        .unwrap_or_else(|| panic!("not an admission error: {e:#}"))
}

#[test]
fn over_budget_sessions_are_refused_with_typed_errors() {
    let (addr, daemon) = start_daemon(ServeConfig {
        max_sessions: 2,
        thread_budget: 2,
        ..ServeConfig::default()
    });
    let mut client = Client::connect(&addr).unwrap();

    let a = client.create("", &tiny_overrides(2), &[]).unwrap();
    client.run(a, 10, false).unwrap();

    // thread budget exhausted: 2 of 2 in use
    let err =
        client.create("", &tiny_overrides(1), &[]).unwrap_err();
    assert_eq!(
        *admission_of(&err),
        AdmissionError::Threads { want: 1, in_use: 2, budget: 2 }
    );

    // suspending releases the threads, so the same request is admitted
    client.suspend(a).unwrap();
    let b = client.create("", &tiny_overrides(1), &[]).unwrap();

    // session-count quota is independent of the thread ledger
    let err =
        client.create("", &tiny_overrides(1), &[]).unwrap_err();
    assert_eq!(
        *admission_of(&err),
        AdmissionError::Sessions { active: 2, max: 2 }
    );

    // resuming `a` needs 2 threads but only 1 is free — a typed
    // refusal, and the parked session must stay parked
    let err = client.resume(a).unwrap_err();
    assert_eq!(
        *admission_of(&err),
        AdmissionError::Threads { want: 2, in_use: 1, budget: 2 }
    );
    assert_eq!(client.stats().unwrap().suspended, 1);

    // closing `b` frees its thread; the resume now goes through and
    // the session continues from where it was parked
    client.close(b).unwrap();
    client.resume(a).unwrap();
    let (step, _) = client.run(a, 10, false).unwrap();
    assert_eq!(step, 20);

    // a plain simulation failure is a server error, not a refusal
    let err = client.run(9999, 10, false).unwrap_err();
    assert!(err.downcast_ref::<AdmissionError>().is_none());
    assert!(format!("{err:#}").contains("server error"), "{err:#}");

    client.shutdown().unwrap();
    daemon.join().unwrap().unwrap();
}

#[test]
fn per_session_thread_cap_refuses_oversized_sessions() {
    let (addr, daemon) = start_daemon(ServeConfig {
        thread_budget: 8,
        max_session_threads: 2,
        ..ServeConfig::default()
    });
    let mut client = Client::connect(&addr).unwrap();
    let err =
        client.create("", &tiny_overrides(4), &[]).unwrap_err();
    assert_eq!(
        *admission_of(&err),
        AdmissionError::SessionThreads { want: 4, max: 2 }
    );
    client.shutdown().unwrap();
    daemon.join().unwrap().unwrap();
}
