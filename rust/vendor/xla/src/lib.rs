//! Offline API stub of the `xla` crate (xla-rs / xla_extension).
//!
//! The real crate links the XLA/PJRT native runtime, which is not
//! available in this build environment. This stub mirrors the exact API
//! surface `cortex::runtime` uses so the PJRT backend *compiles*
//! unchanged; every entry point fails at runtime with a clear error, and
//! the PJRT integration tests skip themselves when no artifacts are
//! present. Swapping this path dependency for the real `xla` crate
//! re-enables the backend with no source changes.

use std::fmt;

/// Error carrying the stub's single message.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT native runtime not available in this build \
             (offline stub at rust/vendor/xla; link the real `xla` crate \
             to enable the PJRT backend)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub: never obtainable, calls fail).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal value.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable("Literal::decompose_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let mut lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.decompose_tuple().is_err());
        assert!(lit.to_vec::<f64>().is_err());
    }
}
