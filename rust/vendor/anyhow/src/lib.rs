//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no network registry, so the workspace vendors
//! the small slice of `anyhow` it actually uses: [`Error`] (a message plus
//! a cause chain), [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros, the [`Context`] extension trait for `Result` and `Option`, and
//! [`Error::downcast`] / [`Error::downcast_ref`] recovering the original
//! typed error from a converted one (the serve daemon's typed admission
//! refusals ride on this). `{e}` prints the outermost message; `{e:#}`
//! prints the whole chain separated by `": "`, matching real `anyhow`'s
//! alternate formatting.

use std::error::Error as StdError;
use std::fmt;

/// `Result` defaulted to [`Error`], as in real `anyhow`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed-free error: an owned message plus an optional cause chain.
/// A layer converted from a typed `std::error::Error` keeps the
/// original value as its payload so it can be downcast back out.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    payload: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None, payload: None }
    }

    /// Build an error from a typed `std::error::Error`, keeping the
    /// value downcastable (identical to the `From` conversion, named
    /// as in real `anyhow`).
    pub fn new<E: StdError + Send + Sync + 'static>(e: E) -> Error {
        Error::from(e)
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
            payload: None,
        }
    }

    /// The cause chain, outermost first (the `{:#}` rendering order).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur.msg.as_str())
        })
    }

    /// Borrow the first error of type `E` in the chain, if any layer
    /// was converted from one.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(hit) =
                e.payload.as_ref().and_then(|p| p.downcast_ref::<E>())
            {
                return Some(hit);
            }
            cur = e.source.as_deref();
        }
        None
    }

    /// Recover the first error of type `E` in the chain by value, or
    /// give the error back unchanged.
    pub fn downcast<E: StdError + Send + Sync + 'static>(
        self,
    ) -> std::result::Result<E, Error> {
        if self.downcast_ref::<E>().is_none() {
            return Err(self);
        }
        // peel context layers until the matching payload is outermost
        let mut cur = self;
        loop {
            let here = cur
                .payload
                .as_ref()
                .is_some_and(|p| p.downcast_ref::<E>().is_some());
            if here {
                let boxed = cur.payload.expect("checked above");
                match boxed.downcast::<E>() {
                    Ok(e) => return Ok(*e),
                    Err(_) => unreachable!("downcast_ref matched"),
                }
            }
            cur = *cur.source.expect("downcast_ref found a match deeper");
        }
    }

    fn from_std(e: &(dyn StdError + 'static)) -> Error {
        Error {
            msg: e.to_string(),
            source: e.source().map(|s| Box::new(Error::from_std(s))),
            payload: None,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Like real anyhow, `Error` does NOT implement `std::error::Error`; that
// is what makes this blanket conversion coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut err = Error::from_std(&e);
        err.payload = Some(Box::new(e));
        err
    }
}

/// Attach context to a fallible value (`Result` or `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

// `Error` is not `std::error::Error`, so this does not overlap with the
// blanket impl above — it is what lets context chain on `anyhow::Result`.
impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn context_chains_and_alternate_formats() {
        let r: Result<()> = Err(io_err()).context("reading checkpoint");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading checkpoint");
        assert_eq!(format!("{e:#}"), "reading checkpoint: disk on fire");
        assert_eq!(
            e.chain().collect::<Vec<_>>(),
            vec!["reading checkpoint", "disk on fire"]
        );
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        let e = anyhow!("rank {} died", 3);
        assert_eq!(format!("{e}"), "rank 3 died");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(format!("{from_string}"), "plain");

        fn fails(x: bool) -> Result<u32> {
            ensure!(x, "x must hold");
            bail!("unreachable arm {}", 1)
        }
        assert_eq!(format!("{}", fails(false).unwrap_err()), "x must hold");
        assert_eq!(
            format!("{}", fails(true).unwrap_err()),
            "unreachable arm 1"
        );
    }

    #[test]
    fn downcast_recovers_the_typed_error() {
        let e: Error = Error::new(io_err());
        assert_eq!(
            e.downcast_ref::<std::io::Error>().unwrap().to_string(),
            "disk on fire"
        );
        // context layers do not hide the payload
        let wrapped = e.context("while snapshotting");
        assert!(wrapped.downcast_ref::<std::io::Error>().is_some());
        assert!(wrapped.downcast_ref::<std::fmt::Error>().is_none());
        let owned = wrapped.downcast::<std::io::Error>().unwrap();
        assert_eq!(owned.to_string(), "disk on fire");

        // a message-only error downcasts to nothing and round-trips
        let plain = anyhow!("no payload here");
        let back = plain.downcast::<std::io::Error>().unwrap_err();
        assert_eq!(format!("{back}"), "no payload here");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "disk on fire");
    }
}
