//! **§III.B ablation** — the mutex-free thread-ownership scheme vs the
//! atomic-delivery pattern of [12]/[13] that the paper eliminates.
//!
//! Same network, same spikes; the CORTEX engine partitions edges by
//! post-owning thread (plain f64 writes), the baseline parallelises over
//! spikes and accumulates with CAS loops. The delta is the cost of
//! synchronisation in the synaptic hot loop.
//!
//! Run: `cargo bench --bench ablation_threading`

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::random_spec;
use cortex::config::{CommMode, DynamicsBackend, MappingKind};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::Table;
use cortex::nest_baseline::{run_nest_simulation, NestRunConfig};

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(random_spec(6_000, 300, 31));
    let steps = 500; // 50 ms
    let mut table = Table::new(
        "threading ablation — owned writes vs atomic delivery (50 ms sim)",
        &["threads", "cortex_owned_s", "baseline_atomic_s", "overhead"],
    );

    for &threads in &[1usize, 2, 4] {
        let cortex_out = run_simulation(
            &spec,
            &RunConfig {
                ranks: 1,
                threads,
                mapping: MappingKind::AreaProcesses,
                comm: CommMode::Serialized,
                backend: DynamicsBackend::Native,
                steps,
                record_limit: None,
                verify_ownership: false,
                artifacts_dir: "artifacts".into(),
                seed: 31,
            },
        )?;
        let nest_out = run_nest_simulation(
            &spec,
            &NestRunConfig {
                ranks: 1,
                threads,
                steps,
                record_limit: None,
                seed: 31,
            },
        );
        table.row(&[
            threads.to_string(),
            format!("{:.3}", cortex_out.wall_seconds),
            format!("{:.3}", nest_out.wall_seconds),
            format!(
                "{:+.1}%",
                100.0
                    * (nest_out.wall_seconds / cortex_out.wall_seconds
                        - 1.0)
            ),
        ]);
    }

    table.emit(Path::new("target/bench_out"), "ablation_threading")?;
    println!(
        "note: this host has one core, so thread counts add scheduling \
         overhead rather than speedup for BOTH engines; the reproduced \
         quantity is the synchronisation overhead of atomic delivery.\n"
    );
    Ok(())
}
