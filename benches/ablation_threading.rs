//! **§III.B ablation** — execution-core and synchronisation overheads in
//! the synaptic hot loop.
//!
//! Three engines over the same network and the same spikes:
//! * CORTEX with the **persistent worker pool** (long-lived compute
//!   threads, channel hand-off per step — the paper's execution model);
//! * CORTEX with the **scoped fallback** (OS threads spawned and joined
//!   every 0.1 ms step — the pre-pool behaviour, isolating spawn cost);
//! * the NEST-style baseline (parallel over spikes, CAS-loop delivery).
//!
//! The pool-vs-scoped delta is pure thread coordination (reported per
//! engine as the timer's `sync` phase); the CORTEX-vs-baseline delta is
//! the cost of atomics in the delivery loop. Multi-thread spike output is
//! asserted bit-identical to single-thread for both CORTEX variants.
//!
//! Run: `cargo bench --bench ablation_threading`

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::random_spec;
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::Table;
use cortex::nest_baseline::{run_nest_simulation, NestRunConfig};

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(random_spec(6_000, 300, 31));
    let steps = 500; // 50 ms
    let mut table = Table::new(
        "threading ablation — persistent pool vs per-step spawn vs \
         atomic delivery (50 ms sim)",
        &[
            "threads",
            "pool_s",
            "pool_sync_ms",
            "scoped_s",
            "scoped_sync_ms",
            "baseline_atomic_s",
            "spawn_overhead",
            "atomic_overhead",
        ],
    );

    let cfg = |threads: usize, exec: ExecMode| RunConfig {
        ranks: 1,
        threads,
        mapping: MappingKind::AreaProcesses,
        comm: CommMode::Serialized,
        backend: DynamicsBackend::Native,
        exec,
        build: BuildMode::TwoPass,
        integrate: IntegrateMode::Vector,
        routing: RoutingMode::Routed,
        comm_group: Vec::new(),
        steps,
        record_limit: Some(u32::MAX),
        verify_ownership: false,
        artifacts_dir: "artifacts".into(),
        seed: 31,
    };

    let mut reference_raster = None;
    for &threads in &[1usize, 2, 4] {
        let pool_out =
            run_simulation(&spec, &cfg(threads, ExecMode::Pool))?;
        let scoped_out =
            run_simulation(&spec, &cfg(threads, ExecMode::Scoped))?;
        // identical record_limit for all three engines so the recorder
        // cost cancels out of the overhead ratios
        let nest_out = run_nest_simulation(
            &spec,
            &NestRunConfig {
                ranks: 1,
                threads,
                steps,
                record_limit: Some(u32::MAX),
                seed: 31,
            },
        );

        // the race-freedom acceptance: thread count and execution
        // backend may not move a single spike
        if let Some(want) = &reference_raster {
            assert_eq!(
                want, &pool_out.raster.events,
                "pool raster diverged at {threads} threads"
            );
        } else {
            reference_raster = Some(pool_out.raster.events.clone());
        }
        assert_eq!(
            reference_raster.as_ref().unwrap(),
            &scoped_out.raster.events,
            "scoped raster diverged at {threads} threads"
        );

        table.row(&[
            threads.to_string(),
            format!("{:.3}", pool_out.wall_seconds),
            format!("{:.2}", pool_out.timer_max.seconds("sync") * 1e3),
            format!("{:.3}", scoped_out.wall_seconds),
            format!("{:.2}", scoped_out.timer_max.seconds("sync") * 1e3),
            format!("{:.3}", nest_out.wall_seconds),
            format!(
                "{:+.1}%",
                100.0
                    * (scoped_out.wall_seconds / pool_out.wall_seconds
                        - 1.0)
            ),
            format!(
                "{:+.1}%",
                100.0
                    * (nest_out.wall_seconds / pool_out.wall_seconds - 1.0)
            ),
        ]);
    }

    table.emit(Path::new("target/bench_out"), "ablation_threading")?;
    println!(
        "spike output bit-identical across threads and execution \
         backends ✓\n\
         note: on a single-core host thread counts add scheduling \
         overhead rather than speedup for ALL engines; the reproduced \
         quantities are the per-step coordination cost (sync: channel \
         round-trip vs spawn/join) and the synchronisation overhead of \
         atomic delivery.\n"
    );
    Ok(())
}
