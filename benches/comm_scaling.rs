//! **Interest-routed spike exchange** — wire volume and exchange time
//! of the routed (per-peer subscription-filtered) exchange vs the
//! broadcast allgather ablation, on two workloads that bracket the
//! design space:
//!
//! * the **Potjans microcircuit** (single area, recurrently dense): at
//!   bench-scale rank counts every rank subscribes to essentially
//!   every peer gid, so the honest expectation is a ratio ≈ 1.0 —
//!   routing must ride at the broadcast bound, never above it;
//! * the **multi-area marmoset network** (paper Fig 7/8: varied
//!   density of synaptic interactions): inhibitory populations project
//!   only within their own area and distance-decayed E→E pairs round
//!   to zero indegree, so with area-aligned ranks the routed share
//!   drops measurably below broadcast — asserted, alongside raster
//!   bit-identity on both workloads.
//!
//! Results land in `target/bench_out/BENCH_comm.json`
//! (`bytes_per_window`, `exchange_ns_per_window`,
//! `routed_over_broadcast`, Tofu-D projections) so CI tracks routing
//! wins alongside build and step numbers.
//!
//! Run: `cargo bench --bench comm_scaling` (rank list as argv to
//! override, e.g. `-- 2 4 8`).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::atlas::potjans::potjans_spec;
use cortex::atlas::NetworkSpec;
use cortex::comm::TofuModel;
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig, RunOutput};
use cortex::metrics::table::human_bytes;
use cortex::metrics::Table;
use cortex::util::json::Json;

const POTJANS_SCALE: f64 = 4_000.0 / 77_169.0;
const STEPS: u64 = 500;
const SEED: u64 = 29;
const THREADS: usize = 2;

fn run(
    spec: &Arc<NetworkSpec>,
    ranks: usize,
    routing: RoutingMode,
) -> anyhow::Result<RunOutput> {
    // serialized exchange so `comm_wait` is the full blocking exchange
    // latency, not the overlap thread's residual
    run_simulation(
        spec,
        &RunConfig {
            ranks,
            threads: THREADS,
            mapping: MappingKind::AreaProcesses,
            comm: CommMode::Serialized,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing,
            steps: STEPS,
            record_limit: Some(u32::MAX),
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed: SEED,
        },
    )
}

fn exchange_ns_per_window(out: &RunOutput) -> f64 {
    let s = out.timer_max.seconds("comm_submit")
        + out.timer_max.seconds("comm_wait");
    s * 1e9 / out.windows.max(1) as f64
}

fn main() -> anyhow::Result<()> {
    let rank_list: Vec<usize> = {
        let cli: Vec<usize> = std::env::args()
            .skip(1)
            .filter_map(|a| a.parse().ok())
            .collect();
        if cli.is_empty() {
            vec![2, 4]
        } else {
            cli
        }
    };
    let nets: Vec<(&str, Arc<NetworkSpec>, bool)> = vec![
        // (name, spec, expect a strict volume reduction?)
        (
            "potjans",
            Arc::new(potjans_spec(POTJANS_SCALE, SEED)),
            false,
        ),
        (
            "marmoset",
            Arc::new(marmoset_spec(
                &MarmosetParams {
                    n_neurons: 4_000,
                    n_areas: 8,
                    indegree: 200,
                    ..Default::default()
                },
                SEED,
            )),
            true,
        ),
    ];
    let tofu = TofuModel::default();

    let mut table = Table::new(
        "comm scaling — interest-routed exchange vs broadcast allgather",
        &[
            "network",
            "ranks",
            "routing",
            "bytes",
            "bytes/window",
            "exch_ns/win",
            "ratio",
            "tofu_us/win",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();

    for (net, spec, expect_reduction) in &nets {
        for &ranks in &rank_list {
            let bcast = run(spec, ranks, RoutingMode::Broadcast)?;
            let routed = run(spec, ranks, RoutingMode::Routed)?;

            // bit-identity is part of the claim: routing only
            // withholds spikes the receiver's sub-graph would drop
            assert_eq!(
                routed.raster.events, bcast.raster.events,
                "{net}/{ranks}r: routed exchange changed the raster"
            );
            assert!(
                routed.comm_bytes <= bcast.comm_bytes,
                "{net}/{ranks}r: routed {} > broadcast {}",
                routed.comm_bytes,
                bcast.comm_bytes
            );
            // the multi-area network has structural sparsity (remote I
            // gids are never subscribed) — the reduction must be real
            if *expect_reduction {
                assert!(
                    (routed.comm_bytes as f64)
                        < 0.95 * bcast.comm_bytes as f64,
                    "{net}/{ranks}r: no measurable reduction \
                     (routed {} vs broadcast {})",
                    routed.comm_bytes,
                    bcast.comm_bytes
                );
            }

            let ratio =
                routed.comm_bytes as f64 / bcast.comm_bytes as f64;
            for (out, routing, ratio) in [
                (&bcast, RoutingMode::Broadcast, 1.0),
                (&routed, RoutingMode::Routed, ratio),
            ] {
                let windows = out.windows.max(1);
                let per_window =
                    out.comm_bytes as f64 / windows as f64;
                let sent_per_rank_window =
                    per_window / ranks as f64;
                let recv_per_rank_window = out.comm_recv_bytes
                    as f64
                    / windows as f64
                    / ranks as f64;
                let tofu_s = match routing {
                    RoutingMode::Broadcast => tofu
                        .allgather_seconds(
                            ranks,
                            sent_per_rank_window,
                        ),
                    RoutingMode::Routed => tofu
                        .routed_exchange_seconds(
                            ranks,
                            sent_per_rank_window,
                            recv_per_rank_window,
                        ),
                };
                table.row(&[
                    net.to_string(),
                    ranks.to_string(),
                    format!("{routing:?}"),
                    human_bytes(out.comm_bytes),
                    format!("{per_window:.0}"),
                    format!("{:.0}", exchange_ns_per_window(out)),
                    format!("{ratio:.3}"),
                    format!("{:.2}", tofu_s * 1e6),
                ]);

                let mut row = BTreeMap::new();
                row.insert(
                    "network".into(),
                    Json::Str(net.to_string()),
                );
                row.insert("ranks".into(), Json::Num(ranks as f64));
                row.insert(
                    "routing".into(),
                    Json::Str(
                        format!("{routing:?}").to_lowercase(),
                    ),
                );
                row.insert(
                    "comm_bytes".into(),
                    Json::Num(out.comm_bytes as f64),
                );
                row.insert(
                    "comm_recv_bytes".into(),
                    Json::Num(out.comm_recv_bytes as f64),
                );
                row.insert(
                    "windows".into(),
                    Json::Num(out.windows as f64),
                );
                row.insert(
                    "bytes_per_window".into(),
                    Json::Num(per_window),
                );
                row.insert(
                    "exchange_ns_per_window".into(),
                    Json::Num(exchange_ns_per_window(out)),
                );
                row.insert(
                    "routed_over_broadcast".into(),
                    Json::Num(ratio),
                );
                row.insert(
                    "tofu_us_per_window".into(),
                    Json::Num(tofu_s * 1e6),
                );
                row.insert(
                    "total_spikes".into(),
                    Json::Num(out.total_spikes as f64),
                );
                rows.push(Json::Obj(row));
            }
        }
    }

    table.emit(Path::new("target/bench_out"), "comm_scaling")?;
    let out_dir = Path::new("target/bench_out");
    std::fs::create_dir_all(out_dir)?;
    let json = Json::Arr(rows).to_string_pretty();
    std::fs::write(out_dir.join("BENCH_comm.json"), json)?;
    println!(
        "wrote target/bench_out/BENCH_comm.json; routed exchange is \
         bit-identical to broadcast, rides at the broadcast bound on \
         the dense microcircuit, and sheds measurable volume on the \
         multi-area network.\n"
    );
    Ok(())
}
