//! **Spike-exchange scaling** — wire volume, frame counts and exchange
//! time of the three routing modes (broadcast allgather, interest-routed
//! per-peer frames, hierarchical relay merge) on two workloads that
//! bracket the design space:
//!
//! * the **Potjans microcircuit** (single area, recurrently dense): at
//!   bench-scale rank counts every rank subscribes to essentially
//!   every peer gid, so the honest expectation is a byte ratio ≈ 1.0 —
//!   routing must ride at the broadcast bound, never above it;
//! * the **multi-area marmoset network** (paper Fig 7/8: varied
//!   density of synaptic interactions): inhibitory populations project
//!   only within their own area and distance-decayed E→E pairs round
//!   to zero indegree, so with area-aligned ranks the routed share
//!   drops measurably below broadcast — asserted, alongside raster
//!   bit-identity on all workload/routing pairs.
//!
//! The hierarchical mode's win is **frames, not bytes**: each spike
//! byte rides up to three hops (gather, relay↔relay merged frame,
//! scatter), but the per-window point-to-point frame count collapses
//! from `R·(R-1)` to `2·(R-G) + G·(G-1)` — asserted strictly below the
//! routed mesh at ≥ 4 ranks. A TCP overlap run per shape additionally
//! records the measured `comm_overlap_ratio` (share of exchange time
//! hidden behind compute), asserted nonzero.
//!
//! Results land in `target/bench_out/BENCH_comm.json`
//! (`bytes_per_window`, `frames_per_window`, `exchange_ns_per_window`,
//! `routed_over_broadcast`, `comm_overlap_ratio`, Tofu-D projections)
//! so CI tracks routing wins alongside build and step numbers.
//!
//! Run: `cargo bench --bench comm_scaling` (rank list as argv to
//! override, e.g. `-- 4 8`).

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::path::Path;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::atlas::potjans::potjans_spec;
use cortex::atlas::NetworkSpec;
use cortex::comm::{frames_per_window, Communicator, TcpComm, TofuModel};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig, RunOutput, Simulation};
use cortex::metrics::table::human_bytes;
use cortex::metrics::Table;
use cortex::util::json::Json;

const POTJANS_SCALE: f64 = 4_000.0 / 77_169.0;
const STEPS: u64 = 500;
const SEED: u64 = 29;
const THREADS: usize = 2;

fn run(
    spec: &Arc<NetworkSpec>,
    ranks: usize,
    routing: RoutingMode,
) -> anyhow::Result<RunOutput> {
    // serialized exchange so `comm_wait` is the full blocking exchange
    // latency, not the overlap thread's residual
    run_simulation(
        spec,
        &RunConfig {
            ranks,
            threads: THREADS,
            mapping: MappingKind::AreaProcesses,
            comm: CommMode::Serialized,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing,
            comm_group: Vec::new(),
            steps: STEPS,
            record_limit: Some(u32::MAX),
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed: SEED,
        },
    )
}

fn exchange_ns_per_window(out: &RunOutput) -> f64 {
    let s = out.timer_max.seconds("comm_submit")
        + out.timer_max.seconds("comm_wait");
    s * 1e9 / out.windows.max(1) as f64
}

/// The folded result of one hierarchical TCP overlap cluster.
struct TcpHierOut {
    events: Vec<(u64, u32)>,
    comm_frames: u64,
    windows: u64,
    /// Min over ranks (the critical-path view `RunOutput` uses).
    overlap_ratio: f64,
}

/// Run `ranks` single-rank TCP sessions on localhost (one per thread)
/// in overlap mode under hierarchical routing: real sockets, a real
/// comm thread, and therefore a *measured* overlap ratio rather than
/// the local serialized zero.
fn tcp_overlap_hier(
    spec: &Arc<NetworkSpec>,
    ranks: usize,
) -> TcpHierOut {
    let listeners: Vec<TcpListener> = (0..ranks)
        .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    let peers: Vec<String> = listeners
        .iter()
        .map(|l| l.local_addr().unwrap().to_string())
        .collect();
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(rank, listener)| {
            let spec = Arc::clone(spec);
            let peers = peers.clone();
            thread::spawn(move || {
                let endpoint = TcpComm::join_with_listener(
                    rank as u16,
                    listener,
                    &peers,
                    Duration::from_secs(60),
                )
                .unwrap();
                let mut sim = Simulation::builder(spec)
                    .ranks(ranks)
                    .threads(THREADS)
                    .mapping(MappingKind::AreaProcesses)
                    .comm(CommMode::Overlap)
                    .routing(RoutingMode::Hierarchical)
                    .record_limit(Some(u32::MAX))
                    .seed(SEED)
                    .transport_with(move |n| {
                        assert_eq!(n, ranks);
                        Ok(vec![(
                            rank,
                            Box::new(endpoint)
                                as Box<dyn Communicator>,
                        )])
                    })
                    .build()
                    .unwrap();
                sim.run_for(STEPS).unwrap();
                sim.finish().unwrap()
            })
        })
        .collect();
    let mut events = Vec::new();
    let mut comm_frames = 0;
    let mut windows = 0;
    let mut overlap_ratio = f64::INFINITY;
    for h in handles {
        let out = h.join().unwrap();
        events.extend(out.raster.events);
        comm_frames += out.comm_frames;
        windows = windows.max(out.windows);
        overlap_ratio = overlap_ratio.min(out.comm_overlap_ratio);
    }
    if !overlap_ratio.is_finite() {
        overlap_ratio = 0.0;
    }
    events.sort_unstable();
    TcpHierOut { events, comm_frames, windows, overlap_ratio }
}

fn main() -> anyhow::Result<()> {
    let rank_list: Vec<usize> = {
        let cli: Vec<usize> = std::env::args()
            .skip(1)
            .filter_map(|a| a.parse().ok())
            .collect();
        if cli.is_empty() {
            vec![2, 4]
        } else {
            cli
        }
    };
    let nets: Vec<(&str, Arc<NetworkSpec>, bool)> = vec![
        // (name, spec, expect a strict volume reduction?)
        (
            "potjans",
            Arc::new(potjans_spec(POTJANS_SCALE, SEED)),
            false,
        ),
        (
            "marmoset",
            Arc::new(marmoset_spec(
                &MarmosetParams {
                    n_neurons: 4_000,
                    n_areas: 8,
                    indegree: 200,
                    ..Default::default()
                },
                SEED,
            )),
            true,
        ),
    ];
    let tofu = TofuModel::default();

    let mut table = Table::new(
        "comm scaling — broadcast vs routed vs hierarchical exchange",
        &[
            "network",
            "ranks",
            "routing",
            "bytes",
            "bytes/window",
            "frames/win",
            "exch_ns/win",
            "ratio",
            "overlap",
            "tofu_us/win",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();

    for (net, spec, expect_reduction) in &nets {
        for &ranks in &rank_list {
            let bcast = run(spec, ranks, RoutingMode::Broadcast)?;
            let routed = run(spec, ranks, RoutingMode::Routed)?;
            let hier = run(spec, ranks, RoutingMode::Hierarchical)?;

            // bit-identity is part of the claim: routing only
            // withholds spikes the receiver's sub-graph would drop,
            // and the hierarchy only changes who carries the bytes
            assert_eq!(
                routed.raster.events, bcast.raster.events,
                "{net}/{ranks}r: routed exchange changed the raster"
            );
            assert_eq!(
                hier.raster.events, bcast.raster.events,
                "{net}/{ranks}r: hierarchical exchange changed the \
                 raster"
            );
            assert!(
                routed.comm_bytes <= bcast.comm_bytes,
                "{net}/{ranks}r: routed {} > broadcast {}",
                routed.comm_bytes,
                bcast.comm_bytes
            );
            // the merge's claim is a frame-count collapse: strictly
            // below the flat mesh once there is more than one group
            assert!(
                hier.comm_frames <= routed.comm_frames,
                "{net}/{ranks}r: hierarchical frames {} above the \
                 routed mesh {}",
                hier.comm_frames,
                routed.comm_frames
            );
            if ranks >= 4 {
                assert!(
                    hier.comm_frames < routed.comm_frames,
                    "{net}/{ranks}r: no frame reduction at {ranks} \
                     ranks ({} vs {})",
                    hier.comm_frames,
                    routed.comm_frames
                );
            }
            // the multi-area network has structural sparsity (remote I
            // gids are never subscribed) — the reduction must be real
            if *expect_reduction {
                assert!(
                    (routed.comm_bytes as f64)
                        < 0.95 * bcast.comm_bytes as f64,
                    "{net}/{ranks}r: no measurable reduction \
                     (routed {} vs broadcast {})",
                    routed.comm_bytes,
                    bcast.comm_bytes
                );
            }

            // a real-socket overlap run for the measured ratio (the
            // serialized local runs above hide nothing by definition)
            let tcp = tcp_overlap_hier(spec, ranks);
            assert_eq!(
                tcp.events, bcast.raster.events,
                "{net}/{ranks}r: hierarchical TCP overlap changed \
                 the raster"
            );
            assert!(
                tcp.overlap_ratio > 0.0,
                "{net}/{ranks}r: overlap hid no exchange time"
            );

            let ratio =
                routed.comm_bytes as f64 / bcast.comm_bytes as f64;
            let hier_ratio =
                hier.comm_bytes as f64 / bcast.comm_bytes as f64;
            let n_groups = ranks.div_ceil(2);
            for (out, routing, ratio, overlap) in [
                (&bcast, RoutingMode::Broadcast, 1.0, 0.0),
                (&routed, RoutingMode::Routed, ratio, 0.0),
                (
                    &hier,
                    RoutingMode::Hierarchical,
                    hier_ratio,
                    tcp.overlap_ratio,
                ),
            ] {
                let windows = out.windows.max(1);
                let per_window =
                    out.comm_bytes as f64 / windows as f64;
                let frames_win =
                    out.comm_frames as f64 / windows as f64;
                let sent_per_rank_window =
                    per_window / ranks as f64;
                let recv_per_rank_window = out.comm_recv_bytes
                    as f64
                    / windows as f64
                    / ranks as f64;
                let tofu_s = match routing {
                    RoutingMode::Broadcast => tofu
                        .allgather_seconds(
                            ranks,
                            sent_per_rank_window,
                        ),
                    RoutingMode::Routed => tofu
                        .routed_exchange_seconds(
                            ranks,
                            sent_per_rank_window,
                            recv_per_rank_window,
                        ),
                    // groups of two: a merged frame bundles both
                    // members' routed traffic
                    RoutingMode::Hierarchical => tofu
                        .hierarchical_exchange_seconds(
                            n_groups,
                            2,
                            sent_per_rank_window,
                            2.0 * sent_per_rank_window,
                        ),
                };
                table.row(&[
                    net.to_string(),
                    ranks.to_string(),
                    format!("{routing:?}"),
                    human_bytes(out.comm_bytes),
                    format!("{per_window:.0}"),
                    format!("{frames_win:.0}"),
                    format!("{:.0}", exchange_ns_per_window(out)),
                    format!("{ratio:.3}"),
                    format!("{overlap:.2}"),
                    format!("{:.2}", tofu_s * 1e6),
                ]);

                let mut row = BTreeMap::new();
                row.insert(
                    "network".into(),
                    Json::Str(net.to_string()),
                );
                row.insert("ranks".into(), Json::Num(ranks as f64));
                row.insert(
                    "routing".into(),
                    Json::Str(
                        format!("{routing:?}").to_lowercase(),
                    ),
                );
                row.insert(
                    "comm_bytes".into(),
                    Json::Num(out.comm_bytes as f64),
                );
                row.insert(
                    "comm_recv_bytes".into(),
                    Json::Num(out.comm_recv_bytes as f64),
                );
                row.insert(
                    "windows".into(),
                    Json::Num(out.windows as f64),
                );
                row.insert(
                    "bytes_per_window".into(),
                    Json::Num(per_window),
                );
                row.insert(
                    "frames_per_window".into(),
                    Json::Num(frames_win),
                );
                row.insert(
                    "exchange_ns_per_window".into(),
                    Json::Num(exchange_ns_per_window(out)),
                );
                row.insert(
                    "routed_over_broadcast".into(),
                    Json::Num(ratio),
                );
                row.insert(
                    "comm_overlap_ratio".into(),
                    Json::Num(overlap),
                );
                row.insert(
                    "tofu_us_per_window".into(),
                    Json::Num(tofu_s * 1e6),
                );
                row.insert(
                    "total_spikes".into(),
                    Json::Num(out.total_spikes as f64),
                );
                rows.push(Json::Obj(row));
            }

            // the TCP overlap run gets its own row: same windows,
            // frames over real sockets, and the measured ratio
            let (flat, two_level) =
                frames_per_window(ranks, n_groups);
            let mut row = BTreeMap::new();
            row.insert("network".into(), Json::Str(net.to_string()));
            row.insert("ranks".into(), Json::Num(ranks as f64));
            row.insert(
                "routing".into(),
                Json::Str("hierarchical_tcp_overlap".into()),
            );
            row.insert(
                "windows".into(),
                Json::Num(tcp.windows as f64),
            );
            row.insert(
                "frames_per_window".into(),
                Json::Num(
                    tcp.comm_frames as f64
                        / tcp.windows.max(1) as f64,
                ),
            );
            row.insert(
                "frames_per_window_bound_flat".into(),
                Json::Num(flat as f64),
            );
            row.insert(
                "frames_per_window_bound_hier".into(),
                Json::Num(two_level as f64),
            );
            row.insert(
                "comm_overlap_ratio".into(),
                Json::Num(tcp.overlap_ratio),
            );
            rows.push(Json::Obj(row));
        }
    }

    table.emit(Path::new("target/bench_out"), "comm_scaling")?;
    let out_dir = Path::new("target/bench_out");
    std::fs::create_dir_all(out_dir)?;
    let json = Json::Arr(rows).to_string_pretty();
    std::fs::write(out_dir.join("BENCH_comm.json"), json)?;
    println!(
        "wrote target/bench_out/BENCH_comm.json; all three routing \
         modes are raster bit-identical, routed rides at the \
         broadcast byte bound on the dense microcircuit and sheds \
         volume on the multi-area network, the hierarchical merge \
         collapses frames/window below the flat mesh at >= 4 ranks, \
         and the TCP overlap runs hide a nonzero share of exchange \
         time.\n"
    );
    Ok(())
}
