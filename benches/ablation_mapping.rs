//! **Fig 8/9/10 ablation** — Area-Processes Mapping vs Random Equivalent
//! Mapping: the number of pre-synaptic neurons each rank must store, the
//! local/remote edge split, and the resulting per-rank memory.
//!
//! The paper's Fig 9/10 example: random mapping forces ~all N sources
//! into every rank's pre table, area mapping keeps it near the area size.
//!
//! Run: `cargo bench --bench ablation_mapping`

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::config::MappingKind;
use cortex::decomp::{
    area_processes_partition, random_equivalent_partition, RankStore,
};
use cortex::metrics::table::human_bytes;
use cortex::metrics::Table;

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(marmoset_spec(
        &MarmosetParams {
            n_neurons: 8_000,
            n_areas: 8,
            indegree: 200,
            ..Default::default()
        },
        23,
    ));
    let n = spec.n_total();

    let mut table = Table::new(
        "mapping ablation — pre-vertex replication and memory per rank",
        &[
            "ranks",
            "mapping",
            "avg_pres",
            "max_pres",
            "remote_edge_%",
            "max_rank_mem",
        ],
    );

    for &ranks in &[4usize, 8, 16] {
        for mapping in
            [MappingKind::AreaProcesses, MappingKind::RandomEquivalent]
        {
            let part = match mapping {
                MappingKind::AreaProcesses => {
                    area_processes_partition(&spec, ranks, 5)
                }
                MappingKind::RandomEquivalent => {
                    random_equivalent_partition(n, ranks, 5)
                }
            };
            let mut pres = Vec::new();
            let mut mems = Vec::new();
            let mut local_e = 0u64;
            let mut remote_e = 0u64;
            for r in 0..ranks {
                let rank_of = part.rank_of.clone();
                let store = RankStore::build(
                    &spec,
                    &part.members[r],
                    move |g| rank_of[g as usize] as usize == r,
                    r as u16,
                    1,
                );
                pres.push(store.n_pres() as f64);
                mems.push(store.memory().total());
                local_e += store.n_local_edges;
                remote_e += store.n_remote_edges;
            }
            let avg =
                pres.iter().sum::<f64>() / ranks as f64;
            let max = pres.iter().cloned().fold(0.0, f64::max);
            table.row(&[
                ranks.to_string(),
                format!("{mapping:?}"),
                format!("{avg:.0}"),
                format!("{max:.0}"),
                format!(
                    "{:.1}",
                    100.0 * remote_e as f64 / (local_e + remote_e) as f64
                ),
                human_bytes(*mems.iter().max().unwrap()),
            ]);
        }
    }

    table.emit(Path::new("target/bench_out"), "ablation_mapping")?;
    println!(
        "paper Fig 9/10: random mapping should push pre counts toward \
         N = {n}, area mapping toward the area size (~{}).\n",
        n / spec.n_areas()
    );
    Ok(())
}
