//! **Fig 18** — the paper's headline evaluation: per-node memory
//! consumption (left panel) and simulation time (right panel) of CORTEX
//! vs the NEST-style baseline across normalized problem sizes.
//!
//! The paper's normalized size 1 is 1M neurons / 3.8G synapses on 384
//! Fugaku nodes; this testbed is one CPU core, so size 1 here is 8 000
//! neurons at indegree 250 (≈2M synapses) on 4 simulated ranks, and the
//! sweep shape — who wins, how the gap grows with problem size — is the
//! reproduced quantity, not Fugaku's absolute numbers.
//!
//! Run: `cargo bench --bench fig18_scaling` (add a size factor list as
//! argv to override, e.g. `-- 0.25 0.5 1`).

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::table::human_bytes;
use cortex::metrics::Table;
use cortex::nest_baseline::{run_nest_simulation, NestRunConfig};

const BASE_NEURONS: usize = 8_000;
const INDEGREE: u32 = 250;
const RANKS: usize = 4;
const THREADS: usize = 1; // one physical core on this testbed; threading is exercised in the ablation
const SIM_MS: f64 = 50.0;

fn main() -> anyhow::Result<()> {
    let sizes: Vec<f64> = {
        let cli: Vec<f64> = std::env::args()
            .skip(1)
            .filter_map(|a| a.parse().ok())
            .collect();
        if cli.is_empty() {
            vec![0.25, 0.5, 1.0, 2.0]
        } else {
            cli
        }
    };

    let mut table = Table::new(
        "Fig 18 — memory and simulation time vs normalized problem size",
        &[
            "size",
            "neurons",
            "synapses",
            "cortex_mem",
            "nest_mem",
            "mem_ratio",
            "cortex_s",
            "nest_s",
            "speedup",
        ],
    );

    for &s in &sizes {
        let n = (BASE_NEURONS as f64 * s) as usize;
        let spec = Arc::new(marmoset_spec(
            &MarmosetParams {
                n_neurons: n,
                n_areas: 8,
                indegree: INDEGREE.min((n / 4) as u32),
                ..Default::default()
            },
            20240710,
        ));
        let steps = (SIM_MS / spec.dt_ms) as u64;

        let cortex_out = run_simulation(
            &spec,
            &RunConfig {
                ranks: RANKS,
                threads: THREADS,
                mapping: MappingKind::AreaProcesses,
                comm: CommMode::Overlap,
                backend: DynamicsBackend::Native,
                exec: ExecMode::Pool,
                build: BuildMode::TwoPass,
                integrate: IntegrateMode::Vector,
                routing: RoutingMode::Routed,
                comm_group: Vec::new(),
                steps,
                record_limit: None,
                verify_ownership: false,
                artifacts_dir: "artifacts".into(),
                seed: 1,
            },
        )?;
        let nest_out = run_nest_simulation(
            &spec,
            &NestRunConfig {
                ranks: RANKS,
                threads: THREADS,
                steps,
                record_limit: None,
                seed: 1,
            },
        );

        let (cm, nm) = (
            cortex_out.memory.max_rank_bytes(),
            nest_out.memory.max_rank_bytes(),
        );
        table.row(&[
            format!("{s}"),
            spec.n_total().to_string(),
            spec.n_edges().to_string(),
            human_bytes(cm),
            human_bytes(nm),
            format!("{:.2}x", nm as f64 / cm as f64),
            format!("{:.3}", cortex_out.wall_seconds),
            format!("{:.3}", nest_out.wall_seconds),
            format!(
                "{:.2}x",
                nest_out.wall_seconds / cortex_out.wall_seconds
            ),
        ]);
    }

    table.emit(Path::new("target/bench_out"), "fig18_scaling")?;
    println!(
        "paper's claim shape: the baseline's memory grows with global N \
         per rank (proxy bookkeeping) while CORTEX stores only its \
         indegree sub-graph; simulation time favours CORTEX via \
         mutex-free delivery + overlap.\n"
    );
    Ok(())
}
