//! **Fig 19** — raster plots of cortical activity from the two
//! simulators. The paper shows V1 rasters from CORTEX and NEST that are
//! "similar to each other with slight differences" (different RNGs).
//! Our substrate is shared, so at matching configuration the engines are
//! spike-exact equal; at *different decompositions* (which is what the
//! paper's two simulators amount to) the rasters diverge spike-by-spike
//! but must agree statistically. Both rasters + their statistics are
//! emitted.
//!
//! The CORTEX side runs on the session facade: a `Simulation` with a
//! population-filtered spike-raster probe over area V1 (the probe path
//! the session API replaces ad-hoc `record_limit` fiddling with).
//!
//! Run: `cargo bench --bench fig19_raster`

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::engine::Simulation;
use cortex::metrics::table::write_csv;
use cortex::metrics::{SpikeRecorder, Table};
use cortex::nest_baseline::{run_nest_simulation, NestRunConfig};
use cortex::probe::SpikeRaster;

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(marmoset_spec(
        &MarmosetParams {
            n_neurons: 4_000,
            n_areas: 4,
            indegree: 150,
            ..Default::default()
        },
        19,
    ));
    let sim_ms = 500.0;
    let steps = (sim_ms / spec.dt_ms) as u64;
    let v1: u32 = spec
        .populations
        .iter()
        .filter(|p| p.area == 0)
        .map(|p| p.n)
        .sum();
    let v1_pops: Vec<&str> = spec
        .populations
        .iter()
        .filter(|p| p.area == 0)
        .map(|p| p.name.as_str())
        .collect();

    let mut sim = Simulation::builder(Arc::clone(&spec))
        .ranks(4)
        .threads(2)
        .seed(19)
        .probe(SpikeRaster::pops("v1", &v1_pops))
        .build()?;
    sim.run_for(steps)?;
    let cortex_raster = SpikeRecorder::from_events(
        sim.drain("v1")?.into_raster()?,
    );
    let cortex_out = sim.finish()?;

    let nest_out = run_nest_simulation(
        &spec,
        &NestRunConfig {
            ranks: 4,
            threads: 1,
            steps,
            record_limit: Some(v1),
            seed: 19,
        },
    );

    let dir = Path::new("target/bench_out");
    write_csv(dir, "fig19_raster_cortex", &cortex_raster.to_csv(0.1))?;
    write_csv(dir, "fig19_raster_nest", &nest_out.raster.to_csv(0.1))?;

    let a = cortex_raster.stats(v1 as usize, 0.1, steps);
    let b = nest_out.raster.stats(v1 as usize, 0.1, steps);
    let mut table = Table::new(
        "Fig 19 — area V1 raster statistics, CORTEX vs NEST-style baseline",
        &["metric", "cortex", "nest_baseline", "rel_diff"],
    );
    let rel = |x: f64, y: f64| {
        if x.max(y) == 0.0 { 0.0 } else { (x - y).abs() / x.abs().max(y.abs()) }
    };
    for (name, x, y) in [
        ("mean_rate_hz", a.mean_rate_hz, b.mean_rate_hz),
        ("mean_isi_cv", a.mean_isi_cv, b.mean_isi_cv),
        ("synchrony", a.synchrony, b.synchrony),
        ("active_fraction", a.active_fraction, b.active_fraction),
    ] {
        table.row(&[
            name.into(),
            format!("{x:.3}"),
            format!("{y:.3}"),
            format!("{:.1}%", 100.0 * rel(x, y)),
        ]);
    }
    table.emit(dir, "fig19_stats")?;
    println!(
        "rasters: target/bench_out/fig19_raster_{{cortex,nest}}.csv \
         ({} / {} events); cortex wall {:.2}s",
        cortex_raster.events.len(),
        nest_out.raster.events.len(),
        cortex_out.wall_seconds
    );
    Ok(())
}
