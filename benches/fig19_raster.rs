//! **Fig 19** — raster plots of cortical activity from the two
//! simulators. The paper shows V1 rasters from CORTEX and NEST that are
//! "similar to each other with slight differences" (different RNGs).
//! Our substrate is shared, so at matching configuration the engines are
//! spike-exact equal; at *different decompositions* (which is what the
//! paper's two simulators amount to) the rasters diverge spike-by-spike
//! but must agree statistically. Both rasters + their statistics are
//! emitted.
//!
//! Run: `cargo bench --bench fig19_raster`

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::config::{CommMode, DynamicsBackend, ExecMode, MappingKind};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::table::write_csv;
use cortex::metrics::Table;
use cortex::nest_baseline::{run_nest_simulation, NestRunConfig};

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(marmoset_spec(
        &MarmosetParams {
            n_neurons: 4_000,
            n_areas: 4,
            indegree: 150,
            ..Default::default()
        },
        19,
    ));
    let sim_ms = 500.0;
    let steps = (sim_ms / spec.dt_ms) as u64;
    let v1: u32 = spec
        .populations
        .iter()
        .filter(|p| p.area == 0)
        .map(|p| p.n)
        .sum();

    let cortex_out = run_simulation(
        &spec,
        &RunConfig {
            ranks: 4,
            threads: 2,
            mapping: MappingKind::AreaProcesses,
            comm: CommMode::Overlap,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            steps,
            record_limit: Some(v1),
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed: 19,
        },
    )?;
    let nest_out = run_nest_simulation(
        &spec,
        &NestRunConfig {
            ranks: 4,
            threads: 1,
            steps,
            record_limit: Some(v1),
            seed: 19,
        },
    );

    let dir = Path::new("target/bench_out");
    write_csv(dir, "fig19_raster_cortex", &cortex_out.raster.to_csv(0.1))?;
    write_csv(dir, "fig19_raster_nest", &nest_out.raster.to_csv(0.1))?;

    let a = cortex_out.raster.stats(v1 as usize, 0.1, steps);
    let b = nest_out.raster.stats(v1 as usize, 0.1, steps);
    let mut table = Table::new(
        "Fig 19 — area V1 raster statistics, CORTEX vs NEST-style baseline",
        &["metric", "cortex", "nest_baseline", "rel_diff"],
    );
    let rel = |x: f64, y: f64| {
        if x.max(y) == 0.0 { 0.0 } else { (x - y).abs() / x.abs().max(y.abs()) }
    };
    for (name, x, y) in [
        ("mean_rate_hz", a.mean_rate_hz, b.mean_rate_hz),
        ("mean_isi_cv", a.mean_isi_cv, b.mean_isi_cv),
        ("synchrony", a.synchrony, b.synchrony),
        ("active_fraction", a.active_fraction, b.active_fraction),
    ] {
        table.row(&[
            name.into(),
            format!("{x:.3}"),
            format!("{y:.3}"),
            format!("{:.1}%", 100.0 * rel(x, y)),
        ]);
    }
    table.emit(dir, "fig19_stats")?;
    println!(
        "rasters: target/bench_out/fig19_raster_{{cortex,nest}}.csv \
         ({} / {} events)",
        cortex_out.raster.events.len(),
        nest_out.raster.events.len()
    );
    Ok(())
}
