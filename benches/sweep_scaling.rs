//! **Build amortization across an ensemble** — the acceptance bench of
//! the topology/state split: one expensive network build shared by
//! N trajectories must cost (nearly) what a single standalone build
//! costs, and the shared store must be resident **once**, not N times.
//!
//! For an N=4 ensemble over a balanced random network this bench
//! asserts (a) total ensemble build time — the one shared store build
//! plus all four state-only trajectory constructions — stays within
//! 1.2× of a single standalone build (+50 ms jitter allowance), and
//! (b) the shared-store memory stays under 1.5× one standalone build's
//! store (standalone × 4 holds it four times). It also re-checks the
//! bit-identity bar end-to-end: every trajectory's raster and
//! checkpoint bytes must equal its standalone counterpart's. Results
//! land in `target/bench_out/BENCH_sweep.json`.
//!
//! Run: `cargo bench --bench sweep_scaling` (`-- <n_neurons>
//! <indegree>` to override the default 8000/100).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use cortex::atlas::random_spec;
use cortex::engine::{Ensemble, RunConfig, Simulation};
use cortex::metrics::table::human_bytes;
use cortex::metrics::Table;
use cortex::util::json::Json;

const RANKS: usize = 2;
const THREADS: usize = 2;
const N_TRAJ: usize = 4;
const STEPS: u64 = 200;

fn main() -> anyhow::Result<()> {
    let argv: Vec<usize> = std::env::args()
        .skip(1)
        .filter_map(|a| a.parse().ok())
        .collect();
    let n = argv.first().copied().unwrap_or(8_000);
    let k = argv.get(1).copied().unwrap_or(100) as u32;
    let spec = Arc::new(random_spec(n, k.min((n / 4) as u32), 7));
    let cfg = RunConfig {
        ranks: RANKS,
        threads: THREADS,
        steps: STEPS,
        record_limit: Some(u32::MAX),
        seed: 7,
        ..Default::default()
    };

    // N standalone runs: each pays its own full network build
    let mut solo_build = Vec::new();
    let mut solo_results = Vec::new();
    let mut solo_store_bytes = 0u64;
    for t in 0..N_TRAJ {
        let mut sim = Simulation::builder(Arc::clone(&spec))
            .run_config(&cfg)
            .drive_seed(t as u64 + 1)
            .build()?;
        solo_build.push(sim.build_seconds());
        if t == 0 {
            let (shared, _) = sim.memory_split()?;
            solo_store_bytes = shared;
        }
        sim.run_for(STEPS)?;
        let mut blob = Vec::new();
        sim.checkpoint(&mut blob)?;
        let out = sim.finish()?;
        solo_results.push((out.raster.events, blob));
    }
    let single_build = solo_build[0];

    // the ensemble: one shared build, then state-only constructions
    let ens = Ensemble::builder(Arc::clone(&spec))
        .run_config(&cfg)
        .build()?;
    let shared_bytes = ens.shared_memory().total_bytes();
    let mut traj_build = Vec::new();
    let mut state_bytes = Vec::new();
    let mut raster_identical = true;
    let mut blob_identical = true;
    for t in 0..N_TRAJ {
        let t0 = Instant::now();
        let mut sim =
            ens.trajectory().drive_seed(t as u64 + 1).build()?;
        traj_build.push(t0.elapsed().as_secs_f64());
        let (_, state) = sim.memory_split()?;
        state_bytes.push(state);
        sim.run_for(STEPS)?;
        let mut blob = Vec::new();
        sim.checkpoint(&mut blob)?;
        let out = sim.finish()?;
        let (solo_raster, solo_blob) = &solo_results[t];
        raster_identical &= *solo_raster == out.raster.events;
        blob_identical &= *solo_blob == blob;
        assert!(out.total_spikes > 0, "trajectory {t} inactive");
    }
    let ens_total =
        ens.build_seconds() + traj_build.iter().sum::<f64>();

    assert!(
        raster_identical,
        "an ensemble trajectory's raster diverged from standalone"
    );
    assert!(
        blob_identical,
        "an ensemble trajectory's checkpoint diverged from standalone"
    );
    // the amortization bar: N=4 trajectories for ~one build
    assert!(
        ens_total <= 1.2 * single_build + 0.05,
        "ensemble total build {ens_total:.3}s exceeds 1.2x the \
         single standalone build {single_build:.3}s"
    );
    // the memory bar: the store is resident once, not four times
    assert!(
        (shared_bytes as f64) < 1.5 * solo_store_bytes as f64,
        "shared store {shared_bytes} B >= 1.5x one standalone \
         store {solo_store_bytes} B"
    );

    let mut table = Table::new(
        "sweep scaling — one build, N=4 trajectories",
        &["quantity", "standalone x4", "ensemble"],
    );
    table.row(&[
        "build_s (total)".into(),
        format!("{:.3}", solo_build.iter().sum::<f64>()),
        format!("{ens_total:.3}"),
    ]);
    table.row(&[
        "store bytes (resident)".into(),
        human_bytes(solo_store_bytes * N_TRAJ as u64),
        human_bytes(shared_bytes),
    ]);
    table.row(&[
        "state bytes / trajectory".into(),
        "-".into(),
        human_bytes(state_bytes.iter().sum::<u64>() / N_TRAJ as u64),
    ]);
    table.row(&[
        "bit-identical rasters".into(),
        "-".into(),
        raster_identical.to_string(),
    ]);
    table.emit(Path::new("target/bench_out"), "sweep_scaling")?;

    let mut obj = BTreeMap::new();
    obj.insert("n_neurons".into(), Json::Num(spec.n_total() as f64));
    obj.insert("n_trajectories".into(), Json::Num(N_TRAJ as f64));
    obj.insert("steps".into(), Json::Num(STEPS as f64));
    obj.insert(
        "single_build_seconds".into(),
        Json::Num(single_build),
    );
    obj.insert(
        "standalone_total_build_seconds".into(),
        Json::Num(solo_build.iter().sum::<f64>()),
    );
    obj.insert(
        "ensemble_shared_build_seconds".into(),
        Json::Num(ens.build_seconds()),
    );
    obj.insert(
        "ensemble_total_build_seconds".into(),
        Json::Num(ens_total),
    );
    obj.insert(
        "build_amortization_ratio".into(),
        Json::Num(ens_total / single_build.max(1e-9)),
    );
    obj.insert(
        "shared_store_bytes".into(),
        Json::Num(shared_bytes as f64),
    );
    obj.insert(
        "standalone_store_bytes_x4".into(),
        Json::Num((solo_store_bytes * N_TRAJ as u64) as f64),
    );
    obj.insert(
        "trajectory_state_bytes".into(),
        Json::Arr(
            state_bytes
                .iter()
                .map(|&b| Json::Num(b as f64))
                .collect(),
        ),
    );
    obj.insert(
        "bit_identical_rasters".into(),
        Json::Bool(raster_identical),
    );
    obj.insert(
        "bit_identical_checkpoints".into(),
        Json::Bool(blob_identical),
    );
    let out_dir = Path::new("target/bench_out");
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(
        out_dir.join("BENCH_sweep.json"),
        Json::Obj(obj).to_string_pretty(),
    )?;
    println!(
        "wrote target/bench_out/BENCH_sweep.json; one shared build \
         served {N_TRAJ} bit-identical trajectories.\n"
    );
    Ok(())
}
