//! **§III.C ablation** — dedicated-communication-thread overlap vs
//! blocking exchange at every window end (paper Fig 16/17).
//!
//! On this single-core host the overlap cannot buy wall-clock time (the
//! comm thread competes with compute), so two quantities are reported:
//! the measured phase split (how much exchange latency the window could
//! hide), and the Tofu-D projection of the hidden communication at the
//! paper's Fugaku scales.
//!
//! Run: `cargo bench --bench ablation_overlap`

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::comm::TofuModel;
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::Table;

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(marmoset_spec(
        &MarmosetParams {
            n_neurons: 6_000,
            n_areas: 8,
            indegree: 200,
            ..Default::default()
        },
        37,
    ));
    let steps = 500;
    let ranks = 4;

    let mut table = Table::new(
        "overlap ablation — spike exchange vs computation (50 ms sim)",
        &["mode", "wall_s", "compute_s", "comm_wait_s", "spikes"],
    );
    let mut measured = Vec::new();
    for comm in [CommMode::Overlap, CommMode::Serialized] {
        let out = run_simulation(
            &spec,
            &RunConfig {
                ranks,
                threads: 2,
                mapping: MappingKind::AreaProcesses,
                comm,
                backend: DynamicsBackend::Native,
                exec: ExecMode::Pool,
                build: BuildMode::TwoPass,
                integrate: IntegrateMode::Vector,
                routing: RoutingMode::Routed,
                comm_group: Vec::new(),
                steps,
                record_limit: None,
                verify_ownership: false,
                artifacts_dir: "artifacts".into(),
                seed: 37,
            },
        )?;
        table.row(&[
            format!("{comm:?}"),
            format!("{:.3}", out.wall_seconds),
            format!("{:.3}", out.timer_max.seconds("compute")),
            format!("{:.3}", out.timer_max.seconds("comm_wait")),
            out.total_spikes.to_string(),
        ]);
        measured.push(out);
    }
    table.emit(Path::new("target/bench_out"), "ablation_overlap")?;

    // identical results is part of the claim: overlap is free
    assert_eq!(
        measured[0].total_spikes, measured[1].total_spikes,
        "overlap must not change results"
    );

    // Fugaku-scale projection: how much of the allgather the window hides
    let out = &measured[0];
    let bytes_per_rank_window =
        out.comm_bytes as f64 / ranks as f64 / out.windows as f64;
    let compute_per_window =
        out.timer_max.seconds("compute") / out.windows as f64;
    let tofu = TofuModel::default();
    let mut proj = Table::new(
        "Tofu-D projection — exchange time vs the window that hides it",
        &["fugaku_ranks", "allgather_s", "window_compute_s", "hidden"],
    );
    for &r in &[64usize, 384, 1536, 6144] {
        // spike volume per rank shrinks as ranks grow (weak-scaling view:
        // same per-rank network, so per-rank payload is held constant)
        let t_comm = tofu.allgather_seconds(r, bytes_per_rank_window);
        proj.row(&[
            r.to_string(),
            format!("{:.2e}", t_comm),
            format!("{:.2e}", compute_per_window),
            if t_comm <= compute_per_window { "fully" } else { "partial" }
                .into(),
        ]);
    }
    proj.emit(Path::new("target/bench_out"), "ablation_overlap_tofu")?;
    Ok(())
}
