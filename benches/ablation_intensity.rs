//! **§I.C ablation** — the computation/communication-ratio argument.
//!
//! The paper evaluates on LIF precisely because it is a "bad case": its
//! per-neuron arithmetic is tiny, so communication and memory effects
//! dominate and the coordinator's optimisations matter. High-intensity
//! models (Hodgkin-Huxley) are "good cases ... too trivial to
//! demonstrate the contribution". This bench puts numbers on that: the
//! per-neuron-step cost of LIF vs AdEx vs HH, and the fraction of a
//! simulation step that spike communication would represent under each
//! (Tofu-D projection at the paper's scale).
//!
//! Run: `cargo bench --bench ablation_intensity`

use std::path::Path;

use cortex::comm::TofuModel;
use cortex::metrics::Table;
use cortex::model::{adex, hh, lif};
use cortex::util::bench::time_median;

const N: usize = 4096;
const STEPS: usize = 50;

fn main() -> anyhow::Result<()> {
    let dt = 0.1;

    // LIF
    let lp = lif::LifParams { i_ext: 380.0, ..Default::default() };
    let props = [lif::Propagators::new(&lp, dt)];
    let mut ls = lif::LifState::new(N, &props, vec![0; N]);
    let zero = vec![0.0; N];
    let t_lif = time_median(5, || {
        let mut spikes = Vec::new();
        for _ in 0..STEPS {
            lif::step_slice(&mut ls, 0, N, &zero, &zero, &props, &mut spikes);
        }
    }) / STEPS as f64;

    // AdEx (constant suprathreshold drive via i_ext)
    let ap = adex::AdexParams { i_ext: 600.0, ..Default::default() };
    let mut as_ = adex::AdexState::new(N, &ap);
    let t_adex = time_median(5, || {
        let mut spikes = Vec::new();
        for _ in 0..STEPS {
            adex::step_slice(
                &mut as_, 0, N, &zero, &zero, &ap, dt, &mut spikes,
            );
        }
    }) / STEPS as f64;

    // HH (10 sub-steps at dt=0.1 ms)
    let hp = hh::HhParams { i_ext: 8.0, ..Default::default() };
    let mut hs = hh::HhState::new(N);
    let t_hh = time_median(3, || {
        let mut spikes = Vec::new();
        for _ in 0..STEPS {
            hh::step_slice(
                &mut hs, 0, N, &zero, &zero, &hp, dt, &mut spikes,
            );
        }
    }) / STEPS as f64;

    let mut table = Table::new(
        "compute intensity — per-neuron dynamics cost (N = 4096)",
        &["model", "ns_per_neuron_step", "vs_lif", "comm_fraction_384r"],
    );
    // communication term: one allgather of a typical spike volume per
    // min-delay window at the paper's 384-node scale, amortised per step
    let tofu = TofuModel::default();
    // 10 Hz × 4096 neurons × 0.1 ms → ~4 spikes/step → ~8 B × 4 per rank
    let comm_per_step = tofu.allgather_seconds(1536, 4.0 * 8.0) / 2.0;
    for (name, t) in [("LIF", t_lif), ("AdEx", t_adex), ("HH", t_hh)] {
        let per_neuron = t / N as f64;
        let compute_per_step = t; // per rank-step at N neurons
        table.row(&[
            name.into(),
            format!("{:.2}", per_neuron * 1e9),
            format!("{:.1}x", t / t_lif),
            format!(
                "{:.1}%",
                100.0 * comm_per_step / (comm_per_step + compute_per_step)
            ),
        ]);
    }
    table.emit(Path::new("target/bench_out"), "ablation_intensity")?;
    println!(
        "paper §I.C: with HH-class intensity the communication share \
         collapses (the 'good case'); LIF keeps it significant — the \
         regime where indegree decomposition and overlap earn their keep.\n"
    );
    Ok(())
}
