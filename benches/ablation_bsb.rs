//! **§V.2 extension** — Brain Simulation Broadcast vs naive allgather.
//!
//! The paper announces BSB as its next communication upgrade: spike
//! packing plus adaptive routing "to decrease the number of small
//! messages in the physical network". This bench measures the packing
//! ratio on real simulated spike traffic, and models message counts and
//! Fugaku-scale (Tofu-D) exchange times for both schemes.
//!
//! Run: `cargo bench --bench ablation_bsb`

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::comm::bsb::{pack, plan_exchange, unpack};
use cortex::comm::{SpikeMsg, TofuModel};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::Table;

fn main() -> anyhow::Result<()> {
    // real spike traffic from a 200 ms marmoset run
    let spec = Arc::new(marmoset_spec(
        &MarmosetParams {
            n_neurons: 6_000,
            n_areas: 8,
            indegree: 200,
            ..Default::default()
        },
        51,
    ));
    let steps = 2000u64;
    let out = run_simulation(
        &spec,
        &RunConfig {
            ranks: 1,
            threads: 2,
            mapping: MappingKind::AreaProcesses,
            comm: CommMode::Serialized,
            backend: DynamicsBackend::Native,
            exec: ExecMode::Pool,
            build: BuildMode::TwoPass,
            integrate: IntegrateMode::Vector,
            routing: RoutingMode::Routed,
            comm_group: Vec::new(),
            steps,
            record_limit: Some(u32::MAX),
            verify_ownership: false,
            artifacts_dir: "artifacts".into(),
            seed: 51,
        },
    )?;

    // slice the raster into min-delay windows and pack each
    let m = spec.min_delay_steps as u64;
    let mut naive_bytes = 0u64;
    let mut packed_bytes = 0u64;
    let mut windows = 0u64;
    let mut w_start = 0u64;
    let mut buf: Vec<SpikeMsg> = Vec::new();
    let mut idx = 0usize;
    let events = &out.raster.events;
    while w_start < steps {
        buf.clear();
        while idx < events.len() && events[idx].0 < w_start + m {
            buf.push(SpikeMsg {
                gid: events[idx].1,
                step: events[idx].0 as u32,
            });
            idx += 1;
        }
        let packed = pack(w_start as u32, &buf)?;
        // round-trip sanity on live data
        assert_eq!(unpack(w_start as u32, &packed)?.len(), buf.len());
        naive_bytes += buf.len() as u64 * 8;
        packed_bytes += packed.len() as u64;
        windows += 1;
        w_start += m;
    }

    let mut t1 = Table::new(
        "BSB packing on simulated spike traffic",
        &["windows", "spikes", "naive_bytes", "packed_bytes", "ratio"],
    );
    t1.row(&[
        windows.to_string(),
        events.len().to_string(),
        naive_bytes.to_string(),
        packed_bytes.to_string(),
        format!("{:.2}x", naive_bytes as f64 / packed_bytes.max(1) as f64),
    ]);
    t1.emit(Path::new("target/bench_out"), "ablation_bsb_packing")?;

    // adaptive routing at scale: per-rank payload per window from the
    // measured average, message counts + Tofu-D times for both schemes
    let tofu = TofuModel::default();
    let avg_packed_per_window = packed_bytes as f64 / windows as f64;
    let mut t2 = Table::new(
        "BSB adaptive routing vs direct exchange (Tofu-D model)",
        &[
            "ranks",
            "direct_msgs",
            "bsb_msgs",
            "direct_s",
            "bsb_s",
            "speedup",
        ],
    );
    for &ranks in &[64usize, 384, 1536, 6144] {
        let plan = plan_exchange(ranks, avg_packed_per_window, 8, 4096.0);
        let direct_msgs = (ranks - 1) as f64;
        // direct: R-1 small messages, latency-bound
        let t_direct = direct_msgs * tofu.latency_us * 1e-6
            + tofu.link_seconds(avg_packed_per_window * direct_msgs);
        // bsb: staged aggregated messages
        let t_bsb = plan.messages_per_rank * tofu.latency_us * 1e-6
            + tofu.link_seconds(plan.bytes_per_rank);
        t2.row(&[
            ranks.to_string(),
            format!("{direct_msgs:.0}"),
            format!("{:.0}", plan.messages_per_rank),
            format!("{t_direct:.2e}"),
            format!("{t_bsb:.2e}"),
            format!("{:.1}x", t_direct / t_bsb),
        ]);
    }
    t2.emit(Path::new("target/bench_out"), "ablation_bsb_routing")?;
    println!(
        "paper §V.2: BSB packs spikes (varint delta coding) and routes \
         them through a dissemination tree — the message-count collapse \
         above is exactly the 'decrease the number of small messages' it \
         promises.\n"
    );
    Ok(())
}
