//! **Kernel bench** — per-neuron cost of the LIF dynamics step: native
//! Rust vs the AOT JAX/Pallas artifact executed through PJRT.
//!
//! Quantifies the dispatch + copy overhead of the PJRT path at the block
//! sizes the artifacts were lowered for (the L1 kernel itself is
//! interpret-mode Pallas lowered to plain HLO; see DESIGN.md §8 for why
//! its TPU performance is analysed statically instead).
//!
//! Run: `cargo bench --bench kernel_pjrt` (needs `make artifacts`).

use std::path::Path;

use cortex::atlas::random_spec;
use cortex::metrics::Table;
use cortex::model::lif::{step_slice, LifParams, LifState, Propagators};
use cortex::runtime::PjrtLif;
use cortex::util::bench::time_median;
use cortex::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    if !Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: run `make artifacts` first");
        return Ok(());
    }

    let params = LifParams::default();
    let props = [Propagators::new(&params, 0.1)];
    let mut table = Table::new(
        "LIF step: native Rust vs AOT JAX/Pallas via PJRT",
        &["n", "native_us", "pjrt_us", "native_ns/neuron", "pjrt_ns/neuron"],
    );

    for &n in &[512usize, 2048, 8192] {
        let mut rng = Rng::new(n as u64);
        let mut state = LifState::new(n, &props, vec![0; n]);
        for i in 0..n {
            state.u[i] = params.e_l + rng.range_f64(0.0, 16.0);
        }
        let in_e: Vec<f64> =
            (0..n).map(|_| rng.range_f64(0.0, 100.0)).collect();
        let in_i: Vec<f64> =
            (0..n).map(|_| -rng.range_f64(0.0, 100.0)).collect();

        let mut native_state = state.clone();
        let t_native = time_median(30, || {
            let mut spikes = Vec::new();
            step_slice(
                &mut native_state, 0, n, &in_e, &in_i, &props, &mut spikes,
            );
        });

        let spec = random_spec(n.max(100), 10, 1);
        let mut pjrt = PjrtLif::load("artifacts", &spec)?;
        let mut pjrt_state = state.clone();
        let t_pjrt = time_median(10, || {
            pjrt.step(&mut pjrt_state, &in_e, &in_i).unwrap();
        });

        table.row(&[
            n.to_string(),
            format!("{:.1}", t_native * 1e6),
            format!("{:.1}", t_pjrt * 1e6),
            format!("{:.2}", t_native * 1e9 / n as f64),
            format!("{:.2}", t_pjrt * 1e9 / n as f64),
        ]);
    }

    table.emit(Path::new("target/bench_out"), "kernel_pjrt")?;
    println!(
        "the PJRT column pays per-dispatch literal copies; the gap \
         narrows with block size (amortised dispatch). On real TPU the \
         same artifact maps the Pallas kernel onto VPU tiles instead \
         (DESIGN.md §8).\n"
    );
    Ok(())
}
