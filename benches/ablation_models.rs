//! **Dynamics-dispatch + kernel-formulation ablation** — two claims:
//!
//! 1. the model-generic layer must be free for the paper's workload: a
//!    LIF-only circuit stepped through the enum-dispatched
//!    `PopulationState` blocks has to produce *bit-identical* results to
//!    the direct `lif::step_slice` fast path, at ≤ 2% overhead;
//! 2. the branch-free vector kernels (`engine.integrate = "vector"`,
//!    the default) must be bit-identical to the scalar ablation on
//!    every model — and measurably faster on LIF, the paper's
//!    communication-bound "bad case" where per-neuron arithmetic is
//!    the entire native compute phase.
//!
//! Three levels:
//! * kernel dispatch: N LIF neurons, direct call vs dispatch — asserts
//!   identical spike trains and bit-identical final state;
//! * kernel formulation: per-model scalar vs vector ns/neuron-step with
//!   bit-identity asserted on spikes and state, recorded in
//!   `target/bench_out/BENCH_step.json` for CI tracking;
//! * engine: the downscaled Potjans microcircuit per neuron model
//!   through the full pool execution core, both kernel formulations,
//!   rasters asserted identical, per-model ns/neuron-step from the
//!   engine's own integrate phase timers.
//!
//! Run: `cargo bench --bench ablation_models`

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use cortex::atlas::potjans::{potjans_spec_with, PotjansModels};
use cortex::config::{
    BuildMode, CommMode, DynamicsBackend, ExecMode, IntegrateMode,
    MappingKind, RoutingMode,
};
use cortex::engine::{integrate_rates, run_simulation, RunConfig};
use cortex::metrics::Table;
use cortex::model::dynamics::{ModelParams, ModelTables, PopulationState};
use cortex::model::lif::{self, LifParams, LifState, Propagators};
use cortex::model::{adex, hh, AdexParams, HhParams};
use cortex::util::bench::time_median;
use cortex::util::json::Json;

const N: usize = 4096;
const STEPS: usize = 200;

fn synth_input(step: usize) -> Vec<f64> {
    (0..N).map(|i| ((i * 13 + step * 7) % 17) as f64 * 12.0).collect()
}

fn main() -> anyhow::Result<()> {
    let dt = 0.1;
    let props = vec![Propagators::new(&LifParams::default(), dt)];
    let tables = ModelTables {
        dt_ms: dt,
        lif_props: props.clone(),
        params: vec![ModelParams::Lif(LifParams::default())],
    };
    let zero = vec![0.0; N];

    // -- kernel level: direct LIF vs dispatched LIF ----------------------
    let mut direct = LifState::new(N, &props, vec![0; N]);
    let mut spikes_direct = Vec::new();
    let t_direct = time_median(7, || {
        for step in 0..STEPS {
            let in_e = synth_input(step);
            lif::step_slice(
                &mut direct,
                0,
                N,
                &in_e,
                &zero,
                &props,
                &mut spikes_direct,
            );
        }
    }) / STEPS as f64;

    let mut via = PopulationState::new(&tables, 0, N);
    let mut spikes_via = Vec::new();
    let t_via = time_median(7, || {
        for step in 0..STEPS {
            let in_e = synth_input(step);
            via.step_block(
                &in_e,
                &zero,
                &tables,
                0,
                0,
                IntegrateMode::Vector,
                &mut spikes_via,
            );
        }
    }) / STEPS as f64;

    // bit-identity: time_median repeats the closure, so both sides ran
    // the same number of rounds over the same deterministic input — and
    // the dispatch side ran the *vector* kernel against the direct
    // scalar fast path, so this is also the tentpole equivalence
    assert_eq!(
        spikes_direct, spikes_via,
        "dispatch changed the LIF spike train"
    );
    let PopulationState::Lif(via_state) = &via else { unreachable!() };
    assert_eq!(via_state.u, direct.u, "dispatch changed membrane state");
    assert_eq!(via_state.ie, direct.ie);
    assert_eq!(via_state.refrac, direct.refrac);

    let overhead = (t_via - t_direct) / t_direct * 100.0;
    let mut kernel = Table::new(
        "LIF kernel: direct fast path vs PopulationState dispatch \
         (N = 4096, bit-identical asserted)",
        &["path", "ns_per_neuron_step", "overhead"],
    );
    for (name, t) in [("direct", t_direct), ("dispatch", t_via)] {
        kernel.row(&[
            name.into(),
            format!("{:.2}", t / N as f64 * 1e9),
            if name == "dispatch" {
                format!("{overhead:+.2}%")
            } else {
                "-".into()
            },
        ]);
    }
    kernel.emit(Path::new("target/bench_out"), "ablation_models_kernel")?;
    println!(
        "dispatch overhead: {overhead:+.2}% (acceptance: <= 2% — one \
         enum branch per block, not per neuron)\n"
    );

    // -- kernel formulation: scalar vs vector per model ------------------
    // Each model steps two identically-seeded states through the same
    // deterministic drive, once per formulation; spike trains and every
    // state array must agree bitwise. The ratio is the ablation's
    // headline number.
    let mut rows: Vec<Json> = Vec::new();
    let mut formulation = Table::new(
        "kernel formulation — scalar vs branch-free vector \
         (N = 4096, bit-identical asserted)",
        &["model", "scalar_ns", "vector_ns", "speedup"],
    );

    // LIF: two parameter sets so the vector path exercises its
    // homogeneous-run segmentation inside the timed loop
    let lp_fast = LifParams { tau_m: 5.0, i_ext: 600.0, ..Default::default() };
    let lp_slow = LifParams { tau_m: 20.0, i_ext: 380.0, ..Default::default() };
    let lif_props =
        vec![Propagators::new(&lp_fast, dt), Propagators::new(&lp_slow, dt)];
    let pidx: Vec<u8> =
        (0..N).map(|i| if i < N / 2 { 0 } else { 1 }).collect();
    let mut lif_s = LifState::new(N, &lif_props, pidx.clone());
    let mut lif_v = LifState::new(N, &lif_props, pidx);
    let mut sp_s = Vec::new();
    let mut sp_v = Vec::new();
    let t_lif_s = time_median(5, || {
        for step in 0..STEPS {
            let in_e = synth_input(step);
            lif::step_slice(
                &mut lif_s, 0, N, &in_e, &zero, &lif_props, &mut sp_s,
            );
        }
    }) / STEPS as f64;
    let t_lif_v = time_median(5, || {
        for step in 0..STEPS {
            let in_e = synth_input(step);
            lif::step_slice_vector(
                &mut lif_v, 0, N, &in_e, &zero, &lif_props, &mut sp_v,
            );
        }
    }) / STEPS as f64;
    assert_eq!(sp_s, sp_v, "LIF: vector changed the spike train");
    assert_eq!(lif_s.u, lif_v.u, "LIF: vector changed membrane state");
    assert_eq!(lif_s.ie, lif_v.ie);
    assert_eq!(lif_s.ii, lif_v.ii);
    assert_eq!(lif_s.refrac, lif_v.refrac);

    // AdEx
    let ap = AdexParams { i_ext: 600.0, ..Default::default() };
    let mut adex_s = adex::AdexState::new(N, &ap);
    let mut adex_v = adex::AdexState::new(N, &ap);
    let mut asp_s = Vec::new();
    let mut asp_v = Vec::new();
    let t_adex_s = time_median(5, || {
        for step in 0..STEPS {
            let in_e = synth_input(step);
            adex::step_slice(
                &mut adex_s, 0, N, &in_e, &zero, &ap, dt, &mut asp_s,
            );
        }
    }) / STEPS as f64;
    let t_adex_v = time_median(5, || {
        for step in 0..STEPS {
            let in_e = synth_input(step);
            adex::step_slice_vector(
                &mut adex_v, 0, N, &in_e, &zero, &ap, dt, &mut asp_v,
            );
        }
    }) / STEPS as f64;
    assert_eq!(asp_s, asp_v, "AdEx: vector changed the spike train");
    assert_eq!(adex_s.v, adex_v.v, "AdEx: vector changed membrane state");
    assert_eq!(adex_s.w, adex_v.w, "AdEx: vector changed adaptation");
    assert_eq!(adex_s.refrac, adex_v.refrac);

    // HH (10 sub-steps per dt; fewer reps keep the bench quick)
    let hp = HhParams { i_ext: 8.0, ..Default::default() };
    let mut hh_s = hh::HhState::new(N);
    let mut hh_v = hh::HhState::new(N);
    let mut hsp_s = Vec::new();
    let mut hsp_v = Vec::new();
    let t_hh_s = time_median(3, || {
        for step in 0..STEPS / 4 {
            let in_e = synth_input(step);
            hh::step_slice(
                &mut hh_s, 0, N, &in_e, &zero, &hp, dt, &mut hsp_s,
            );
        }
    }) / (STEPS / 4) as f64;
    let t_hh_v = time_median(3, || {
        for step in 0..STEPS / 4 {
            let in_e = synth_input(step);
            hh::step_slice_vector(
                &mut hh_v, 0, N, &in_e, &zero, &hp, dt, &mut hsp_v,
            );
        }
    }) / (STEPS / 4) as f64;
    assert_eq!(hsp_s, hsp_v, "HH: vector changed the spike train");
    assert_eq!(hh_s.v, hh_v.v, "HH: vector changed membrane state");
    assert_eq!(hh_s.m, hh_v.m);
    assert_eq!(hh_s.h, hh_v.h);
    assert_eq!(hh_s.n, hh_v.n);

    for (name, ts, tv) in [
        ("lif", t_lif_s, t_lif_v),
        ("adex", t_adex_s, t_adex_v),
        ("hh", t_hh_s, t_hh_v),
    ] {
        let scalar_ns = ts / N as f64 * 1e9;
        let vector_ns = tv / N as f64 * 1e9;
        formulation.row(&[
            name.into(),
            format!("{scalar_ns:.2}"),
            format!("{vector_ns:.2}"),
            format!("{:.2}x", scalar_ns / vector_ns),
        ]);
        let mut row = BTreeMap::new();
        row.insert("model".into(), Json::Str(name.into()));
        row.insert("n_neurons".into(), Json::Num(N as f64));
        row.insert("threads".into(), Json::Num(1.0));
        row.insert("ns_per_neuron_step".into(), Json::Num(vector_ns));
        row.insert(
            "scalar_ns_per_neuron_step".into(),
            Json::Num(scalar_ns),
        );
        row.insert("speedup".into(), Json::Num(scalar_ns / vector_ns));
        rows.push(Json::Obj(row));
    }
    formulation
        .emit(Path::new("target/bench_out"), "ablation_models_formulation")?;
    // the perf acceptance, with slack for noisy CI runners: the LIF
    // vector kernel must at minimum not lose to the scalar one
    assert!(
        t_lif_v <= t_lif_s * 1.10,
        "LIF vector kernel slower than scalar: {:.2} vs {:.2} ns",
        t_lif_v / N as f64 * 1e9,
        t_lif_s / N as f64 * 1e9,
    );

    // -- engine level: Potjans microcircuit per neuron model -------------
    let lif_mp = ModelParams::Lif(LifParams::default());
    let variants: [(&str, PotjansModels); 3] = [
        ("LIF (paper workload)", PotjansModels { e: lif_mp, i: lif_mp }),
        (
            "AdEx E / LIF I",
            PotjansModels {
                e: ModelParams::Adex(AdexParams::default()),
                i: lif_mp,
            },
        ),
        (
            "HH E / LIF I",
            PotjansModels {
                e: ModelParams::Hh(HhParams::default()),
                i: lif_mp,
            },
        ),
    ];
    let mut table = Table::new(
        "Potjans microcircuit (~1600 neurons, 60 ms, 2r x 2t) per model \
         — vector vs scalar kernels, rasters asserted identical",
        &["models", "wall_s", "scalar_wall_s", "spikes", "steps_per_s"],
    );
    let steps = 600u64;
    for (name, models) in &variants {
        let spec =
            Arc::new(potjans_spec_with(1600.0 / 77_169.0, 23, models));
        let run = |integrate: IntegrateMode| {
            run_simulation(
                &spec,
                &RunConfig {
                    ranks: 2,
                    threads: 2,
                    mapping: MappingKind::AreaProcesses,
                    comm: CommMode::Overlap,
                    backend: DynamicsBackend::Native,
                    exec: ExecMode::Pool,
                    build: BuildMode::TwoPass,
                    integrate,
                    routing: RoutingMode::Routed,
                    comm_group: Vec::new(),
                    steps,
                    record_limit: Some(u32::MAX),
                    verify_ownership: false,
                    artifacts_dir: "artifacts".into(),
                    seed: 23,
                },
            )
        };
        let out = run(IntegrateMode::Vector)?;
        let out_s = run(IntegrateMode::Scalar)?;
        assert_eq!(
            out.raster.events, out_s.raster.events,
            "{name}: kernel formulation changed the raster"
        );
        table.row(&[
            (*name).into(),
            format!("{:.3}", out.wall_seconds),
            format!("{:.3}", out_s.wall_seconds),
            format!("{}", out.total_spikes),
            format!("{:.0}", steps as f64 / out.wall_seconds),
        ]);
        // the runtime instrument: per-model ns/neuron-step from the
        // engine's own integrate phase timers (aggregate over workers)
        for (m, n, ns) in integrate_rates(&spec, &out.timer_sum, steps) {
            println!(
                "  {name}: integrate {m:?} — {n} neurons, \
                 {ns:.1} ns/neuron-step (vector)"
            );
        }
    }
    table.emit(Path::new("target/bench_out"), "ablation_models")?;

    let out_dir = Path::new("target/bench_out");
    std::fs::create_dir_all(out_dir)?;
    let json = Json::Arr(rows).to_string_pretty();
    std::fs::write(out_dir.join("BENCH_step.json"), json)?;
    println!(
        "\nwrote target/bench_out/BENCH_step.json; scalar and vector \
         kernels bit-identical on all models, rasters identical through \
         the full engine.\n"
    );
    Ok(())
}
