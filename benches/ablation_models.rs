//! **Dynamics-dispatch ablation** — the model-generic layer must be
//! free for the paper's workload: a LIF-only circuit stepped through the
//! enum-dispatched `PopulationState` blocks has to produce *bit-identical*
//! results to the direct `lif::step_slice` fast path (the seed engine's
//! hard-wired loop), at ≤ 2% overhead. AdEx / HH rows quantify what the
//! heterogeneity buys in compute intensity (paper §I.C).
//!
//! Two levels:
//! 1. kernel: N LIF neurons driven with identical synthetic input via
//!    the direct call vs the dispatch — asserts identical spike trains
//!    and bit-identical final state, reports the overhead;
//! 2. engine: the downscaled Potjans microcircuit (pure LIF, the
//!    acceptance workload) through the full pool execution core, plus
//!    AdEx-E and HH-E variants of the same circuit for throughput.
//!
//! Run: `cargo bench --bench ablation_models`

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::potjans::{potjans_spec_with, PotjansModels};
use cortex::config::{BuildMode, CommMode, DynamicsBackend, ExecMode, MappingKind};
use cortex::engine::{run_simulation, RunConfig};
use cortex::metrics::Table;
use cortex::model::dynamics::{ModelParams, ModelTables, PopulationState};
use cortex::model::lif::{self, LifParams, LifState, Propagators};
use cortex::model::{AdexParams, HhParams};
use cortex::util::bench::time_median;

const N: usize = 4096;
const STEPS: usize = 200;

fn synth_input(step: usize) -> Vec<f64> {
    (0..N).map(|i| ((i * 13 + step * 7) % 17) as f64 * 12.0).collect()
}

fn main() -> anyhow::Result<()> {
    let dt = 0.1;
    let props = vec![Propagators::new(&LifParams::default(), dt)];
    let tables = ModelTables {
        dt_ms: dt,
        lif_props: props.clone(),
        params: vec![ModelParams::Lif(LifParams::default())],
    };
    let zero = vec![0.0; N];

    // -- kernel level: direct LIF vs dispatched LIF ----------------------
    let mut direct = LifState::new(N, &props, vec![0; N]);
    let mut spikes_direct = Vec::new();
    let t_direct = time_median(7, || {
        for step in 0..STEPS {
            let in_e = synth_input(step);
            lif::step_slice(
                &mut direct,
                0,
                N,
                &in_e,
                &zero,
                &props,
                &mut spikes_direct,
            );
        }
    }) / STEPS as f64;

    let mut via = PopulationState::new(&tables, 0, N);
    let mut spikes_via = Vec::new();
    let t_via = time_median(7, || {
        for step in 0..STEPS {
            let in_e = synth_input(step);
            via.step_block(&in_e, &zero, &tables, 0, 0, &mut spikes_via);
        }
    }) / STEPS as f64;

    // bit-identity: time_median repeats the closure, so both sides ran
    // the same number of rounds over the same deterministic input
    assert_eq!(
        spikes_direct, spikes_via,
        "dispatch changed the LIF spike train"
    );
    let PopulationState::Lif(via_state) = &via else { unreachable!() };
    assert_eq!(via_state.u, direct.u, "dispatch changed membrane state");
    assert_eq!(via_state.ie, direct.ie);
    assert_eq!(via_state.refrac, direct.refrac);

    let overhead = (t_via - t_direct) / t_direct * 100.0;
    let mut kernel = Table::new(
        "LIF kernel: direct fast path vs PopulationState dispatch \
         (N = 4096, bit-identical asserted)",
        &["path", "ns_per_neuron_step", "overhead"],
    );
    for (name, t) in [("direct", t_direct), ("dispatch", t_via)] {
        kernel.row(&[
            name.into(),
            format!("{:.2}", t / N as f64 * 1e9),
            if name == "dispatch" {
                format!("{overhead:+.2}%")
            } else {
                "-".into()
            },
        ]);
    }
    kernel.emit(Path::new("target/bench_out"), "ablation_models_kernel")?;
    println!(
        "dispatch overhead: {overhead:+.2}% (acceptance: <= 2% — one \
         enum branch per block, not per neuron)\n"
    );

    // -- engine level: Potjans microcircuit per neuron model -------------
    let lif = ModelParams::Lif(LifParams::default());
    let variants: [(&str, PotjansModels); 3] = [
        ("LIF (paper workload)", PotjansModels { e: lif, i: lif }),
        (
            "AdEx E / LIF I",
            PotjansModels {
                e: ModelParams::Adex(AdexParams::default()),
                i: lif,
            },
        ),
        (
            "HH E / LIF I",
            PotjansModels {
                e: ModelParams::Hh(HhParams::default()),
                i: lif,
            },
        ),
    ];
    let mut table = Table::new(
        "Potjans microcircuit (~1600 neurons, 60 ms, 2r x 2t) per model",
        &["models", "wall_s", "spikes", "steps_per_s"],
    );
    for (name, models) in &variants {
        let spec =
            Arc::new(potjans_spec_with(1600.0 / 77_169.0, 23, models));
        let out = run_simulation(
            &spec,
            &RunConfig {
                ranks: 2,
                threads: 2,
                mapping: MappingKind::AreaProcesses,
                comm: CommMode::Overlap,
                backend: DynamicsBackend::Native,
                exec: ExecMode::Pool,
                build: BuildMode::TwoPass,
                steps: 600,
                record_limit: None,
                verify_ownership: false,
                artifacts_dir: "artifacts".into(),
                seed: 23,
            },
        )?;
        table.row(&[
            (*name).into(),
            format!("{:.3}", out.wall_seconds),
            format!("{}", out.total_spikes),
            format!("{:.0}", 600.0 / out.wall_seconds),
        ]);
    }
    table.emit(Path::new("target/bench_out"), "ablation_models")?;
    Ok(())
}
