//! **Fig 12/15 ablation** — delay-sorted edge layout vs unsorted.
//!
//! The paper reorders each thread's synaptic interactions by delay so a
//! time step touches contiguous runs and ring-buffer slots in order.
//! This micro-bench isolates exactly that effect: one delivery pass over
//! identical edges, once with the store's (pre, delay)-sorted runs and
//! once with each run shuffled.
//!
//! Run: `cargo bench --bench ablation_delay_order`

use std::path::Path;
use std::sync::Arc;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::decomp::{area_processes_partition, RankStore};
use cortex::engine::ring::InputRing;
use cortex::metrics::Table;
use cortex::util::bench::{black_box, time_median};
use cortex::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let spec = Arc::new(marmoset_spec(
        &MarmosetParams {
            n_neurons: 8_000,
            n_areas: 8,
            indegree: 300,
            ..Default::default()
        },
        41,
    ));
    let part = area_processes_partition(&spec, 1, 41);
    let store = RankStore::build(&spec, &part.members[0], |_| true, 0, 1);
    let te = &store.threads[0];
    let n_pres = store.n_pres();

    // a plausible spiking set: 2% of pres fire
    let mut rng = Rng::new(7);
    let spikes: Vec<u32> = (0..n_pres as u32)
        .filter(|_| rng.bool(0.02))
        .collect();

    // shuffled copy: same edges, randomised order within each pre run
    let mut sh_post = te.post.clone();
    let mut sh_weight = te.weight.clone();
    let mut sh_delay = te.delay.clone();
    for p in 0..n_pres {
        let r = te.run(p);
        let idx: Vec<usize> = {
            let mut v: Vec<usize> = (0..r.len()).collect();
            rng.shuffle(&mut v);
            v
        };
        for (k, &j) in idx.iter().enumerate() {
            sh_post[r.start + k] = te.post[r.start + j];
            sh_weight[r.start + k] = te.weight[r.start + j];
            sh_delay[r.start + k] = te.delay[r.start + j];
        }
    }

    let ring_len = store.max_delay as usize + 1;
    let mut ring = InputRing::new(store.n_posts(), ring_len);

    let mut deliver = |post: &[u32], weight: &[f64], delay: &[u16]| {
        for &p in &spikes {
            let r = te.run(p as usize);
            for ei in r {
                let due = 100 + delay[ei] as u64;
                ring.add(post[ei] as usize, due, weight[ei]);
            }
        }
    };

    let reps = 15;
    let t_sorted =
        time_median(reps, || deliver(&te.post, &te.weight, &te.delay));
    let t_shuffled =
        time_median(reps, || deliver(&sh_post, &sh_weight, &sh_delay));
    black_box(&ring);

    let n_edges: usize =
        spikes.iter().map(|&p| te.run(p as usize).len()).sum();
    let mut table = Table::new(
        "delay-order ablation — one delivery pass over the same edges",
        &["layout", "time_ms", "ns_per_edge", "speedup"],
    );
    table.row(&[
        "delay-sorted (paper)".into(),
        format!("{:.3}", t_sorted * 1e3),
        format!("{:.2}", t_sorted * 1e9 / n_edges as f64),
        format!("{:.2}x", t_shuffled / t_sorted),
    ]);
    table.row(&[
        "shuffled".into(),
        format!("{:.3}", t_shuffled * 1e3),
        format!("{:.2}", t_shuffled * 1e9 / n_edges as f64),
        "1.00x".into(),
    ]);
    table.emit(Path::new("target/bench_out"), "ablation_delay_order")?;
    println!(
        "{} spiking pres, {} edges delivered per pass, ring {} slots\n",
        spikes.len(),
        n_edges,
        ring_len
    );
    Ok(())
}
