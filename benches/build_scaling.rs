//! **Build-phase scaling** — the construction-time counterpart of
//! `fig18_scaling`: wall time and *peak* memory of materialising each
//! rank's indegree sub-graph, two-pass streaming builder vs the serial
//! staging ablation, on the same marmoset spec family Fig 18 sweeps.
//!
//! The paper reports network-construction time separately from
//! simulation time (§V), and its maximum-problem-size argument only
//! holds if construction — not just steady state — fits in a rank's
//! memory share. This bench asserts the streaming builder's analytic
//! peak stays ≤ 1.5× the final store (the staging path holds ~3×), and
//! records the trajectory in `target/bench_out/BENCH_build.json`
//! (`n_edges`, `build_seconds`, `peak_bytes`, ...) so CI tracks
//! construction numbers alongside simulation ones.
//!
//! Run: `cargo bench --bench build_scaling` (size-factor list as argv
//! to override, e.g. `-- 0.25 0.5`).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use cortex::atlas::marmoset::{marmoset_spec, MarmosetParams};
use cortex::decomp::{area_processes_partition, RankStore};
use cortex::metrics::table::human_bytes;
use cortex::metrics::Table;
use cortex::util::json::Json;

const BASE_NEURONS: usize = 8_000;
const INDEGREE: u32 = 250;
const RANKS: usize = 4;
const THREADS: usize = 4;

fn main() -> anyhow::Result<()> {
    let sizes: Vec<f64> = {
        let cli: Vec<f64> = std::env::args()
            .skip(1)
            .filter_map(|a| a.parse().ok())
            .collect();
        if cli.is_empty() {
            vec![0.5, 1.0]
        } else {
            cli
        }
    };

    let mut table = Table::new(
        "build scaling — two-pass streaming vs serial staging builder",
        &[
            "size",
            "neurons",
            "synapses",
            "build_s",
            "serial_s",
            "peak",
            "serial_peak",
            "peak/final",
            "serial/final",
        ],
    );
    let mut rows: Vec<Json> = Vec::new();

    for &s in &sizes {
        let n = (BASE_NEURONS as f64 * s) as usize;
        let spec = Arc::new(marmoset_spec(
            &MarmosetParams {
                n_neurons: n,
                n_areas: 8,
                indegree: INDEGREE.min((n / 4) as u32),
                ..Default::default()
            },
            20240710,
        ));
        let part = area_processes_partition(&spec, RANKS, 1);

        let mut build_s: f64 = 0.0;
        let mut serial_s: f64 = 0.0;
        let mut peak = 0u64;
        let mut serial_peak = 0u64;
        let mut final_bytes = 0u64;
        let mut n_edges = 0u64;
        // worst per-rank ratio (ranks are imbalanced; max-peak and
        // max-final can come from different ranks, so the ratio of the
        // maxima is not any rank's actual ratio)
        let mut ratio: f64 = 0.0;
        let mut serial_ratio: f64 = 0.0;
        for r in 0..RANKS {
            let rank_of = part.rank_of.clone();
            let t0 = Instant::now();
            let store = RankStore::build(
                &spec,
                &part.members[r],
                move |g| rank_of[g as usize] as usize == r,
                r as u16,
                THREADS,
            );
            build_s = build_s.max(t0.elapsed().as_secs_f64());

            let rank_of = part.rank_of.clone();
            let t1 = Instant::now();
            let serial = RankStore::build_serial(
                &spec,
                &part.members[r],
                move |g| rank_of[g as usize] as usize == r,
                r as u16,
                THREADS,
            );
            serial_s = serial_s.max(t1.elapsed().as_secs_f64());

            assert!(
                store.same_graph(&serial),
                "size {s} rank {r}: builders disagree"
            );
            let m = store.memory();
            let fin =
                m.get("posts") + m.get("pres") + m.get("edges");
            // the acceptance bound: streaming construction must never
            // need more than ~1.5× the store it is building
            assert!(
                store.build.peak_bytes as f64
                    <= 1.5 * fin as f64 + 65536.0,
                "size {s} rank {r}: peak {} exceeds 1.5× final {fin}",
                store.build.peak_bytes
            );
            peak = peak.max(store.build.peak_bytes);
            serial_peak = serial_peak.max(serial.build.peak_bytes);
            final_bytes = final_bytes.max(fin);
            ratio = ratio
                .max(store.build.peak_bytes as f64 / fin as f64);
            serial_ratio = serial_ratio
                .max(serial.build.peak_bytes as f64 / fin as f64);
            n_edges += store.n_edges();
        }

        table.row(&[
            format!("{s}"),
            spec.n_total().to_string(),
            n_edges.to_string(),
            format!("{build_s:.3}"),
            format!("{serial_s:.3}"),
            human_bytes(peak),
            human_bytes(serial_peak),
            format!("{ratio:.2}x"),
            format!("{serial_ratio:.2}x"),
        ]);

        let mut row = BTreeMap::new();
        row.insert("size".into(), Json::Num(s));
        row.insert(
            "n_neurons".into(),
            Json::Num(spec.n_total() as f64),
        );
        row.insert("n_edges".into(), Json::Num(n_edges as f64));
        row.insert("build_seconds".into(), Json::Num(build_s));
        row.insert(
            "serial_build_seconds".into(),
            Json::Num(serial_s),
        );
        row.insert("peak_bytes".into(), Json::Num(peak as f64));
        row.insert(
            "serial_peak_bytes".into(),
            Json::Num(serial_peak as f64),
        );
        row.insert(
            "final_bytes".into(),
            Json::Num(final_bytes as f64),
        );
        row.insert("peak_over_final".into(), Json::Num(ratio));
        row.insert(
            "serial_peak_over_final".into(),
            Json::Num(serial_ratio),
        );
        rows.push(Json::Obj(row));
    }

    table.emit(Path::new("target/bench_out"), "build_scaling")?;
    let out_dir = Path::new("target/bench_out");
    std::fs::create_dir_all(out_dir)?;
    let json = Json::Arr(rows).to_string_pretty();
    std::fs::write(out_dir.join("BENCH_build.json"), json)?;
    println!(
        "wrote target/bench_out/BENCH_build.json; streaming peak stays \
         ≤1.5× the final store where the staging builder holds ~3×.\n"
    );
    Ok(())
}
